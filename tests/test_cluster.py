"""ClusterScheduler stack: trace determinism, MISO-style placement,
fragmentation stranding + repack recovery (the bench_cluster scenario),
modeled migration cost, power-cap admission, the progress-based engine
(retro-active stretching, frozen-mode bit-identity with the PR 2
scheduler, elastic SLO rescue), the Action API (PolicySpec allowlist,
deprecation shims, cross-pod migration over the DCN, look-ahead
chaining), live SliceRuntime execution, and metrics sanity."""
import hashlib
import subprocess
import sys
from collections import Counter

import numpy as np
import pytest

from repro.cluster import (ClusterScheduler, PolicySpec, TraceConfig,
                           elastic_showcase, fragmentation_showcase,
                           generate_trace, grow_showcase,
                           lookahead_showcase, migration_showcase,
                           parse_actions, preemption_showcase,
                           select_cheapest)
from repro.cluster.placement import (FirstFitPolicy, FragAwarePolicy,
                                     feasible_options, get_policy)
from repro.cluster.trace import (BATCH, KIND_PRIORITY, KINDS, SERVING,
                                 TRAINING, Job)
from repro.core.hw import V5E_POD


# ---------------------------------------------------------------------------
# trace generator
# ---------------------------------------------------------------------------
def test_trace_deterministic_and_mixed():
    a = generate_trace(TraceConfig(seed=3))
    b = generate_trace(TraceConfig(seed=3))
    assert a == b
    assert a != generate_trace(TraceConfig(seed=4))
    kinds = Counter(j.kind for j in a)
    assert set(kinds) <= set(KINDS) and len(kinds) == 3
    arrivals = [j.arrival_s for j in a]
    assert arrivals == sorted(arrivals)
    assert all(j.requests > 0 for j in a if j.kind == SERVING)
    assert all(j.u_compute is not None and j.u_compute < 0.2
               for j in a if j.kind == BATCH)


def test_feasible_options_pinned_profile():
    job = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10,
              profile="4s.64c")
    opts = feasible_options(job)
    assert [p.name for p, _, _ in opts] == ["4s.64c"]
    free = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10)
    assert len(feasible_options(free)) > 1


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
def test_first_fit_takes_smallest_feasible():
    sched = ClusterScheduler(n_pods=1, policy="first_fit")
    job = Job(0, SERVING, "llama3-8b", "decode_32k", 0.0, 100)
    cands = sched.policy.candidates(job, sched.pods, sched.chip, 0.0, None)
    smallest = feasible_options(job)[0][0]
    assert cands[0].profile.name == smallest.name
    assert cands[0].origin == (0, 0)


def test_frag_aware_candidates_sorted_and_scored():
    sched = ClusterScheduler(n_pods=2, policy="frag")
    job = Job(0, TRAINING, "qwen3-32b", "train_4k", 0.0, 20)
    cands = sched.policy.candidates(job, sched.pods, sched.chip, 0.0, None)
    assert cands, "empty cluster must offer candidates"
    flags = [c.meets_deadline for c in cands]
    assert flags == sorted(flags, reverse=True)
    for c in cands:
        assert c.perf_per_chip > 0
        assert c.largest_after >= 0


def test_get_policy_unknown():
    with pytest.raises(KeyError):
        get_policy("optimal")


# ---------------------------------------------------------------------------
# the stranding scenario (acceptance criterion: repack places a job
# first-fit leaves queued, on the same deterministic trace)
# ---------------------------------------------------------------------------
STRANDED = 10


def _run_showcase(policy):
    sched = ClusterScheduler(n_pods=1, policy=policy, horizon_s=3000.0)
    records, metrics = sched.run(fragmentation_showcase())
    big = next(r for r in records if r.job.job_id == STRANDED)
    return sched, records, metrics, big


def test_first_fit_strands_big_job():
    _, _, metrics, big = _run_showcase("first_fit")
    assert not big.placed, "first-fit should leave the 8x16 job queued"
    assert metrics.left_queued == 1
    assert metrics.repacks == 0
    assert metrics.frag_time_avg > 0.3  # scattered holes persist


def test_repack_places_stranded_job_with_migration_cost():
    sched, records, metrics, big = _run_showcase("frag_repack")
    assert big.placed and big.finished
    assert big.profile_name == "8s.128c"
    assert metrics.left_queued == 0
    assert metrics.repacks == 1 and metrics.repack_failures == 0
    assert metrics.migrated_bytes > 0
    assert metrics.migration_s == pytest.approx(
        metrics.migrated_bytes / sched._pod_host_bw)
    # the stranded job starts only after the migration delay
    assert big.finish_s > big.place_s + big.job.duration_s
    # defrag is visible in the time-averaged fragmentation ratio
    assert metrics.frag_time_avg < 0.05
    sched.pods[0].partitioner.validate()


def test_repack_stretches_moved_running_jobs():
    _, records, _, _ = _run_showcase("frag_repack")
    moved_long = [r for r in records
                  if r.job.duration_s == 10_000.0 and r.placed]
    assert moved_long, "long jobs should be running when repack fires"
    stretched = [r for r in moved_long
                 if r.finish_s > r.place_s + r.job.duration_s]
    assert stretched, "migration must delay at least one moved running job"


# ---------------------------------------------------------------------------
# power-cap admission (paper §V-B)
# ---------------------------------------------------------------------------
def _hot_job(jid, arrival, duration):
    return Job(jid, TRAINING, "llama3-8b", "train_4k", arrival, 1,
               profile="8s.128c", duration_s=duration, u_compute=1.0)


def test_power_cap_defers_second_hot_job():
    # two full-power 128-chip jobs together draw 51.2 kW > the 43.5 kW cap
    # (throttle 0.79 < 0.8) -> the second waits for the first to finish
    jobs = [_hot_job(0, 0.0, 100.0), _hot_job(1, 1.0, 100.0)]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             min_throttle=0.8)
    records, metrics = sched.run(jobs)
    second = next(r for r in records if r.job.job_id == 1)
    assert metrics.power_deferrals >= 1
    assert second.place_s == pytest.approx(100.0)  # admitted at completion
    # with the gate off, both co-run and the pod throttles instead
    sched2 = ClusterScheduler(n_pods=1, policy="frag_repack",
                              min_throttle=0.0)
    records2, metrics2 = sched2.run(jobs)
    second2 = next(r for r in records2 if r.job.job_id == 1)
    assert metrics2.power_deferrals == 0
    assert second2.place_s == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# end-to-end on a generated trace
# ---------------------------------------------------------------------------
def test_scheduler_deterministic_and_metrics_sane():
    trace = generate_trace(TraceConfig(seed=0, n_jobs=16))
    m1 = ClusterScheduler(n_pods=2, policy="frag_repack").run(trace)[1]
    m2 = ClusterScheduler(n_pods=2, policy="frag_repack").run(trace)[1]
    assert m1 == m2
    assert m1.placed == m1.n_jobs == 16
    assert m1.completed == 16 and m1.still_running == 0
    assert 0.0 < m1.chip_hour_utilization <= 1.0
    assert 0.0 <= m1.slo_attainment <= 1.0
    assert 0.0 <= m1.frag_time_avg <= 1.0
    assert m1.energy_J > 0 and m1.makespan_s > 0


def test_pods_empty_after_drain():
    trace = generate_trace(TraceConfig(seed=1, n_jobs=10))
    sched = ClusterScheduler(n_pods=2, policy="frag")
    sched.run(trace)
    for pod in sched.pods:
        assert pod.partitioner.free_chips() == V5E_POD.n_chips
        assert not pod.jobs and not pod.slice_jobs
        pod.partitioner.validate()


def test_scheduler_single_use():
    sched = ClusterScheduler(n_pods=1)
    sched.run([])
    with pytest.raises(AssertionError):
        sched.run([])


# ---------------------------------------------------------------------------
# progress-based engine (PerfModel / PodSimulator rewrite)
# ---------------------------------------------------------------------------
# Golden numbers recorded from the PR 2 scheduler (fixed-at-admission
# durations) on this exact seeded trace, before the PodSimulator rewrite.
# ``frozen_durations=True`` must reproduce them bit-for-bit.
_PR2_TRACE = dict(seed=0, n_jobs=48, mean_interarrival_s=5.0)
_PR2_GOLDEN = {
    "makespan_s": 5841.312618401943,
    "energy_J": 164866198.0380577,
    "mean_queue_delay_s": 149.83535556820502,
    "p95_queue_delay_s": 352.84254173889997,
    "slo_attainment": 0.16666666666666666,
    "chip_hour_utilization": 0.38907819980013525,
    "frag_time_avg": 0.29202000328138994,
    "repacks": 1,
    "power_deferrals": 0,
    "migrated_bytes": 3573412790272,
    "migration_s": 3.489660928,
}
_PR2_TIMELINE_SHA = \
    "429696d0b32a6c03aec769b791fd0683498c4ec9749b15f463820d6b919fb9c8"


def test_frozen_durations_bit_identical_to_pr2_scheduler():
    trace = generate_trace(TraceConfig(**_PR2_TRACE))
    records, m = ClusterScheduler(n_pods=1, policy="frag_repack",
                                  frozen_durations=True).run(trace)
    for key, want in _PR2_GOLDEN.items():
        assert getattr(m, key) == want, key   # exact, not approx
    timeline = repr([(r.job.job_id, r.place_s, r.finish_s) for r in records])
    assert (hashlib.sha256(timeline.encode()).hexdigest()
            == _PR2_TIMELINE_SHA)


def _stretch_jobs():
    # two full-power 128-chip training jobs; together they exceed the cap
    return [Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 50,
                profile="8s.128c", u_compute=1.0),
            Job(1, TRAINING, "llama3-8b", "train_4k", 10.0, 50,
                profile="8s.128c", u_compute=1.0)]


def test_later_arrival_retroactively_stretches_in_flight_job():
    frozen_rec, _ = ClusterScheduler(
        n_pods=1, policy="frag", min_throttle=0.0,
        frozen_durations=True).run(_stretch_jobs())
    progress_rec, _ = ClusterScheduler(
        n_pods=1, policy="frag", min_throttle=0.0).run(_stretch_jobs())
    f_a = next(r for r in frozen_rec if r.job.job_id == 0)
    p_a = next(r for r in progress_rec if r.job.job_id == 0)
    # frozen: job 0's duration was fixed when it ran alone (throttle 1.0);
    # progress: job 1's arrival re-solves the mix and stretches job 0
    assert p_a.finish_s > f_a.finish_s
    # the stretch is retro-active within the run: the projection at
    # placement time (duration_s) is exceeded by the actual finish
    assert p_a.finish_s > p_a.place_s + p_a.duration_s
    # and job 1 finishes *earlier* than frozen mode predicts: once job 0
    # completes, the survivor speeds back up (frozen can't model that)
    f_b = next(r for r in frozen_rec if r.job.job_id == 1)
    p_b = next(r for r in progress_rec if r.job.job_id == 1)
    assert p_b.finish_s < f_b.finish_s


def test_pinned_duration_traces_identical_in_both_modes():
    # the fragmentation showcase pins every duration, so the progress
    # engine must reproduce the frozen timeline exactly
    a = ClusterScheduler(n_pods=1, policy="frag_repack",
                         horizon_s=3000.0).run(fragmentation_showcase())[1]
    b = ClusterScheduler(n_pods=1, policy="frag_repack", horizon_s=3000.0,
                         frozen_durations=True).run(
                             fragmentation_showcase())[1]
    assert a == b


# ---------------------------------------------------------------------------
# elastic shrink (online profile re-selection: SLO miss -> SLO hit)
# ---------------------------------------------------------------------------
def _run_elastic(elastic):
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             horizon_s=3000.0, elastic=elastic)
    records, metrics = sched.run(elastic_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 2)
    victim = next(r for r in records if r.job.job_id == 0)
    return sched, metrics, deadline_job, victim


def test_without_elastic_deadline_job_misses_slo():
    _, metrics, deadline_job, victim = _run_elastic(False)
    assert not deadline_job.placed          # queued behind two long holders
    assert metrics.shrinks == 0
    assert metrics.slo_attainment == 0.0
    assert victim.profile_name == "8s.128c" and not victim.shrunk


def test_elastic_shrink_turns_slo_miss_into_hit():
    sched, metrics, deadline_job, victim = _run_elastic(True)
    # the low-priority batch job was shrunk to the smallest feasible profile
    assert metrics.shrinks == 1
    assert victim.shrunk and victim.profile_name == "1s.16c"
    # the deadline job placed immediately (plus migration delay) and hit
    assert deadline_job.placed and deadline_job.finished
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # the shrink is priced as a migration over the pod's host links
    assert metrics.migrated_bytes > 0
    assert metrics.migration_s == pytest.approx(
        metrics.migrated_bytes / sched._pod_host_bw)
    # the victim paid: its finish moved past its pinned duration
    assert victim.finish_s > victim.place_s + victim.job.duration_s
    assert metrics.slo_attainment > 0.0
    sched.pods[0].partitioner.validate()


def test_elastic_shrink_lifts_power_gate():
    # the pod HAS an aligned origin for the deadline job, but admitting it
    # next to the full-power batch holder trips the power gate; shrinking
    # the batch job cuts its dynamic draw and lifts the cap
    jobs = [Job(0, BATCH, "gpt2-124m", "decode_32k", 0.0, 1,
                profile="8s.128c", duration_s=10_000.0, u_compute=1.0),
            Job(1, TRAINING, "llama3-8b", "train_4k", 5.0, 1,
                profile="8s.128c", duration_s=200.0, u_compute=1.0,
                slo_factor=2.0)]
    base_rec, base_m = ClusterScheduler(
        n_pods=1, policy="frag_repack", min_throttle=0.8).run(jobs)
    blocked = next(r for r in base_rec if r.job.job_id == 1)
    assert base_m.power_deferrals == 1
    assert blocked.place_s == pytest.approx(10_000.0)  # waited out the holder
    el_rec, el_m = ClusterScheduler(
        n_pods=1, policy="frag_repack", min_throttle=0.8,
        elastic=True).run(jobs)
    rescued = next(r for r in el_rec if r.job.job_id == 1)
    assert el_m.shrinks == 1 and el_m.power_deferrals == 0
    assert rescued.place_s == pytest.approx(5.0)
    assert rescued.finish_s <= rescued.deadline_s


def test_elastic_never_hurts_generated_trace_slo():
    trace = generate_trace(TraceConfig(seed=0, n_jobs=48,
                                       mean_interarrival_s=5.0))
    base = ClusterScheduler(n_pods=1, policy="frag_repack").run(trace)[1]
    el = ClusterScheduler(n_pods=1, policy="frag_repack",
                          elastic=True).run(trace)[1]
    assert el.slo_attainment >= base.slo_attainment


# ---------------------------------------------------------------------------
# checkpoint preemption (priorities: SLO miss -> hit where shrink cannot)
# ---------------------------------------------------------------------------
def test_trace_priorities_follow_kind():
    for j in generate_trace(TraceConfig(seed=2)):
        assert j.priority == KIND_PRIORITY[j.kind]


def _run_preemption(priorities, elastic=True):
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             priorities=priorities, elastic=elastic)
    records, metrics = sched.run(preemption_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 2)
    victim = next(r for r in records if r.job.job_id == 0)
    return sched, metrics, deadline_job, victim


def test_without_priorities_deadline_job_misses_slo():
    # elastic shrink alone cannot mint an 8x16 origin here (the shrunk
    # victim stays at its origin), so the deadline job waits and misses
    _, metrics, deadline_job, victim = _run_preemption(False)
    assert metrics.preemptions == 0 and metrics.shrinks == 0
    assert deadline_job.place_s > deadline_job.deadline_s
    assert deadline_job.finish_s > deadline_job.deadline_s
    assert victim.preemptions == 0 and not victim.suspended


def test_preemption_turns_slo_miss_into_hit():
    sched, metrics, deadline_job, victim = _run_preemption(True)
    # the deadline job placed immediately after the priced save delay
    assert metrics.preemptions == 1 and metrics.resumes == 1
    assert metrics.shrinks == 0     # shrink could not mint the origin
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finished
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # the save delay is the checkpoint volume over the pod's host links
    # (checkpoint_bytes counts save + restore, i.e. the volume twice)
    save_s = victim.checkpoint_bytes / 2 / sched._pod_host_bw
    assert deadline_job.finish_s == pytest.approx(
        10.0 + save_s + deadline_job.job.duration_s)
    sched.pods[0].partitioner.validate()


def test_preempted_job_resumes_with_work_done_preserved():
    sched, metrics, deadline_job, victim = _run_preemption(True)
    assert victim.finished and victim.preemptions == 1 and victim.resumes == 1
    assert victim.suspend_s == pytest.approx(10.0)
    # resumed as soon as the deadline job freed the rectangle
    assert victim.resume_s == pytest.approx(deadline_job.finish_s)
    # no lost progress beyond the priced checkpoint delta: total wall time
    # = nominal work + the suspension gap + the save+restore seconds paid
    nominal = victim.job.steps * victim.step_time_s
    gap = victim.resume_s - victim.suspend_s
    restore_s = victim.checkpoint_delay_s / 2   # save_s == restore_s here
    assert victim.finish_s == pytest.approx(
        victim.job.arrival_s + nominal + gap + restore_s)
    assert metrics.wasted_checkpoint_chip_s == pytest.approx(
        128 * victim.checkpoint_delay_s)
    # the comparator recorded checkpoint traffic, not slice migration
    assert victim.checkpoint_bytes > 0


def test_preemption_requires_strictly_lower_priority():
    # same showcase but the batch holder outranks the arrival: no eviction
    from dataclasses import replace
    jobs = [j if j.job_id != 0 else replace(j, priority=5)
            for j in preemption_showcase()]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             priorities=True)
    records, metrics = sched.run(jobs)
    assert metrics.preemptions == 0
    deadline_job = next(r for r in records if r.job.job_id == 2)
    assert deadline_job.place_s > deadline_job.deadline_s


def test_preemption_skipped_when_save_delay_blows_deadline():
    # slack of ~0.04 s < the ~0.15 s save drain: suspending the victim
    # could not save the SLO, so the scheduler must leave it running
    from dataclasses import replace
    jobs = [j if j.job_id != 2 else replace(j, slo_factor=1.0001)
            for j in preemption_showcase()]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             priorities=True)
    records, metrics = sched.run(jobs)
    victim = next(r for r in records if r.job.job_id == 0)
    assert metrics.preemptions == 0 and metrics.resumes == 0
    assert victim.preemptions == 0 and victim.finished
    # sanity: a slack comfortably above the save drain does preempt
    assert ClusterScheduler(n_pods=1, policy="frag_repack",
                            priorities=True).run(
        preemption_showcase())[1].preemptions == 1


def test_preemption_picks_cheapest_victim():
    # two priority-0 batch holders could each mint the rectangle; the
    # scheduler must checkpoint the one with the least resident state
    # (gpt2 ~144 GiB), not the first by job id (qwen3 ~1 TiB)
    jobs = [
        Job(job_id=0, kind=BATCH, arch="qwen3-32b", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=10_000.0, u_compute=0.05, priority=0),
        Job(job_id=1, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=10_000.0, u_compute=0.05, priority=0),
        Job(job_id=2, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="8s.128c",
            duration_s=400.0, u_compute=0.3, slo_factor=2.0, priority=2),
    ]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             priorities=True)
    records, metrics = sched.run(jobs)
    expensive = next(r for r in records if r.job.job_id == 0)
    cheap = next(r for r in records if r.job.job_id == 1)
    assert metrics.preemptions == 1
    assert cheap.preemptions == 1 and expensive.preemptions == 0


def test_evicted_victim_resumes_immediately_when_space_exists():
    # the victim's 4x4 blocks the only 8x8 origin, but after eviction a
    # different 4x4 hole is still free: the victim must resume in the
    # same event, not idle until the next completion drains the queue
    jobs = [
        Job(job_id=0, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="1s.16c",
            duration_s=10_000.0, u_compute=0.05, priority=0),
        Job(job_id=1, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="2s.32c",
            duration_s=10_000.0, u_compute=0.3, priority=1),
        Job(job_id=2, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=10_000.0, u_compute=0.3, priority=1),
        Job(job_id=3, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="4s.64c",
            duration_s=400.0, u_compute=0.3, slo_factor=2.0, priority=2),
    ]
    sched = ClusterScheduler(n_pods=1, policy="first_fit", priorities=True)
    records, metrics = sched.run(jobs)
    victim = next(r for r in records if r.job.job_id == 0)
    deadline_job = next(r for r in records if r.job.job_id == 3)
    assert metrics.preemptions == 1 and metrics.resumes == 1
    assert deadline_job.finished
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # resumed at eviction time, in the remaining free 4x4 hole
    assert victim.resume_s == pytest.approx(10.0)
    restore_s = victim.checkpoint_delay_s / 2
    assert victim.finish_s == pytest.approx(10.0 + restore_s + 9_990.0)
    sched.pods[0].partitioner.validate()


def test_preemption_preserves_unpaid_migration_delay():
    # a repack at t=101 charges the moved batch jobs ~0.7 s of host-link
    # delay; a deadline arrival at t=101.5 evicts one mid-burn. The
    # unpaid remainder must survive the suspension: the resume owes
    # restore + leftover migration debt on top of the remaining wall time
    jobs = fragmentation_showcase() + [
        Job(job_id=11, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=101.5, steps=1, profile="1s.16c", duration_s=50.0,
            u_compute=0.3, priority=2)]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             priorities=True)
    records, metrics = sched.run(jobs)
    assert metrics.repacks == 1 and metrics.preemptions == 1
    victim = next(r for r in records if r.preemptions)
    assert victim.job.kind == BATCH and victim.resumes == 1
    debt = metrics.migration_s - 0.5        # burned 101 -> 101.5 only
    assert debt > 0
    restore_s = victim.checkpoint_delay_s / 2
    # pinned 10 000 s wall: 101 s ran pre-repack, none during the delay
    # burn, so 9 899 s remained at eviction
    assert victim.finish_s == pytest.approx(
        victim.resume_s + restore_s + debt + 9_899.0)


def test_infeasible_heavy_victim_does_not_mask_feasible_one():
    # victim A (priority 0, ~1 TiB resident) is scanned first, but its
    # ~1.1 s save drain alone would blow the ~0.6 s deadline slack; the
    # probe must fall through to victim B (priority 1, ~144 GiB,
    # ~0.15 s save) instead of abandoning the pod
    jobs = [
        Job(job_id=0, kind=BATCH, arch="qwen3-32b", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=10_000.0, u_compute=0.05, priority=0),
        Job(job_id=1, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=10_000.0, u_compute=0.05, priority=1),
        Job(job_id=2, kind=TRAINING, arch="qwen3-32b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="8s.128c", duration_s=400.0,
            u_compute=0.3, slo_factor=1.0015, priority=2),
    ]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             priorities=True)
    records, metrics = sched.run(jobs)
    heavy = next(r for r in records if r.job.job_id == 0)
    light = next(r for r in records if r.job.job_id == 1)
    deadline_job = next(r for r in records if r.job.job_id == 2)
    # without the per-victim check the probe dies on A and the deadline
    # job queues to a miss; with it, B is evicted and the SLO holds
    assert light.preemptions == 1
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finished
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # bonus cascade, by priority design: the resumed B (priority 1)
    # immediately reclaims chips from A (priority 0) — its own slack is
    # huge, so evicting the heavy victim is legal for *it*
    assert heavy.preemptions == 1 and light.resumes == 1
    assert metrics.preemptions == 2 and metrics.resumes == 2
    assert metrics.completed == 3


def test_drain_survives_nested_resume_of_suspended_victim():
    # the hard case: a deadline job D queues at t=5 (power gate), victim
    # Y is checkpoint-evicted at t=10 by another arrival, and at t=50 a
    # completion lets D preempt victim Z mid-drain — the nested rescue
    # resumes Y while the drain sweep still holds it in its snapshot.
    # The sweep must not place Y a second time (double-admit crash).
    def tj(jid, prof, dur, u, prio, arrive=0.0, arch="llama3-8b"):
        return Job(job_id=jid, kind=TRAINING, arch=arch, shape="train_4k",
                   arrival_s=arrive, steps=1, profile=prof, duration_s=dur,
                   u_compute=u, priority=prio, slo_factor=1000.0)
    jobs = [
        Job(job_id=0, kind=BATCH, arch="llama3-8b", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="4s.64c", duration_s=10_000.0,
            u_compute=0.05, priority=0),                       # Z
        tj(1, "4s.64c", 10_000.0, 1.0, 1),                     # holder
        tj(2, "2s.32c", 50.0, 1.0, 1),                         # short C
        Job(job_id=3, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="1s.16c", duration_s=10_000.0,
            u_compute=0.05, priority=0),                       # Y
        tj(4, "1s.16c", 10_000.0, 1.0, 1),
        tj(5, "1s.16c", 10_000.0, 1.0, 1),
        tj(6, "1s.16c", 10_000.0, 1.0, 1),
        tj(7, "1s.16c", 10_000.0, 1.0, 1),
        tj(8, "1s.16c", 10_000.0, 1.0, 1),                     # pod full
        tj(9, "4s.64c", 200.0, 1.0, 2, arrive=5.0),            # D (blocked)
        tj(10, "1s.16c", 200.0, 0.05, 2, arrive=10.0),         # evicts Y
    ]
    sched = ClusterScheduler(n_pods=1, policy="first_fit",
                             priorities=True, min_throttle=0.9)
    records, metrics = sched.run(jobs)     # must not raise
    y = next(r for r in records if r.job.job_id == 3)
    z = next(r for r in records if r.job.job_id == 0)
    d = next(r for r in records if r.job.job_id == 9)
    assert metrics.preemptions == 2 and metrics.resumes == 2
    assert y.preemptions == 1 and y.resumes == 1
    assert z.preemptions == 1 and z.resumes == 1
    # Y was resumed by D's mid-drain preempt, in the same event
    assert d.place_s == pytest.approx(50.0)
    assert y.resume_s == pytest.approx(50.0)
    assert metrics.completed == len(jobs)
    sched.pods[0].partitioner.validate()


def test_select_cheapest_comparator():
    from repro.cluster.actions import Action, ActionOutcome

    class _Opt(Action):
        def __init__(self, kind, cost, vid, feasible=True):
            super().__init__(None)
            self.kind = kind
            self._vid = vid
            self.outcome = ActionOutcome(feasible, cost_s=cost)

        @property
        def victim_id(self):
            return self._vid

    assert select_cheapest([]) is None
    assert select_cheapest([None, None]) is None
    mk = _Opt
    a, b = mk("preempt", 1.0, 7), mk("shrink", 2.0, 3)
    assert select_cheapest([a, b]) is a          # cheapest wins
    c, d = mk("preempt", 1.0, 7), mk("shrink", 1.0, 3)
    assert select_cheapest([c, d]) is d          # tie -> least disruptive
    e, f = mk("shrink", 1.0, 9), mk("shrink", 1.0, 3)
    assert select_cheapest([e, f]) is f          # then lowest victim id
    g, h = mk("migrate", 1.0, 1), mk("preempt", 1.0, 1)
    assert select_cheapest([g, h]) is g          # migrate beats preempt
    i = mk("shrink", 0.1, 1, feasible=False)
    assert select_cheapest([i, a]) is a          # infeasible filtered out


def test_frozen_priorities_off_reproduces_pr3_golden():
    # the full golden check lives in
    # test_frozen_durations_bit_identical_to_pr2_scheduler; this pins the
    # flag semantics — priorities/grow default OFF and change nothing
    trace = generate_trace(TraceConfig(**_PR2_TRACE))
    m_flags = ClusterScheduler(n_pods=1, policy="frag_repack",
                               frozen_durations=True, priorities=False,
                               grow=False).run(trace)[1]
    for key, want in _PR2_GOLDEN.items():
        assert getattr(m_flags, key) == want, key


# ---------------------------------------------------------------------------
# elastic grow (extend(): absorb freed neighbour chips)
# ---------------------------------------------------------------------------
def _run_grow(grow):
    sched = ClusterScheduler(n_pods=1, policy="frag_repack", grow=grow)
    records, metrics = sched.run(grow_showcase())
    job = next(r for r in records if r.job.job_id == 0)
    return sched, metrics, job


def test_grow_absorbs_freed_neighbors_and_improves_finish():
    _, m_off, base = _run_grow(False)
    sched, m_on, grown = _run_grow(True)
    assert m_off.grows == 0 and not base.grown
    assert m_on.grows == 1 and grown.grown
    assert grown.profile_name == "8s.128c"      # 4s.64c extended in place
    assert grown.finish_s < base.finish_s       # projected finish improved
    # priced symmetrically to shrink: resident state over the host links
    assert m_on.migrated_bytes > 0
    assert m_on.migration_s == pytest.approx(
        m_on.migrated_bytes / sched._pod_host_bw)
    sched.pods[0].partitioner.validate()


def test_grow_respects_power_gate():
    # the 16x16 grow (256 chips at u=1.0) would throttle below the default
    # 0.8 gate, so the scheduler settles for 8s.128c; with the gate
    # dropped it takes the full pod
    _, _, job = _run_grow(True)
    assert job.profile_name == "8s.128c"
    sched = ClusterScheduler(n_pods=1, policy="frag_repack", grow=True,
                             min_throttle=0.0)
    records, metrics = sched.run(grow_showcase())
    job = next(r for r in records if r.job.job_id == 0)
    assert job.profile_name == "16s.256c" and metrics.grows == 1


def test_grow_projected_finish_improves_in_finish_times():
    # drive the simulator directly: the re-solved projection after a grow
    # resize moves the job's entry in finish_times earlier
    from repro.core.hw import V5E_POD as pod
    from repro.core.perfmodel import PodSimulator
    sim = PodSimulator(pod)
    sim.admit(0, 64, 0.9, 4.0, 100, 0.0)
    sim.advance(40.0)
    before = sim.finish_times(40.0)[0]
    sim.resize(0, 128, 0.9, 2.0)    # grown: twice the chips, half the step
    after = sim.finish_times(40.0)[0]
    assert after < before


def test_queued_jobs_have_first_claim_over_grow():
    # fill the bottom half so an arrival queues; when the short neighbour
    # frees its rectangle the *queued* job takes it — the running job may
    # only grow into it after that tenant also completes
    jobs = grow_showcase() + [
        Job(job_id=2, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="4s.64c", duration_s=500.0,
            u_compute=0.3, priority=1),
        Job(job_id=3, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, steps=1, profile="8s.128c", duration_s=5000.0,
            u_compute=0.3, priority=1)]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack", grow=True)
    records, metrics = sched.run(jobs)
    queued = next(r for r in records if r.job.job_id == 2)
    grower = next(r for r in records if r.job.job_id == 0)
    # the freed 8x8 went to the queued job at t=50, not to the grower ...
    assert queued.place_s == pytest.approx(50.0)
    # ... which grows only at t=550 when that tenant finishes: well after
    # the ~1026 s finish an immediate t=50 grow would have produced
    assert metrics.grows == 1 and grower.grown
    assert grower.profile_name == "8s.128c"
    assert grower.finish_s > 1200.0


# ---------------------------------------------------------------------------
# Action API surface: PolicySpec, deprecation shims, exports
# ---------------------------------------------------------------------------
def test_policy_spec_validates_and_canonicalizes():
    spec = PolicySpec(actions=("preempt", "shrink", "shrink"))
    assert spec.actions == ("shrink", "preempt")   # canonical order, deduped
    assert spec.enabled("shrink") and not spec.enabled("grow")
    with pytest.raises(ValueError):
        PolicySpec(actions=("evict",))
    with pytest.raises(ValueError):
        PolicySpec(selector="optimal")
    assert parse_actions("grow, migrate") == ("grow", "migrate")
    assert parse_actions("") == ()
    with pytest.raises(ValueError):
        parse_actions("shrink,teleport")


def test_policy_spec_from_flags_matches_booleans():
    assert PolicySpec.from_flags() == PolicySpec()
    assert PolicySpec.from_flags(elastic=True, priorities=True) == \
        PolicySpec(actions=("shrink", "preempt"))
    assert PolicySpec.from_flags(grow=True).actions == ("grow",)


def test_deprecated_booleans_warn_and_map_to_spec():
    with pytest.warns(DeprecationWarning):
        sched = ClusterScheduler(n_pods=1, elastic=True, priorities=True)
    assert sched.spec == PolicySpec(actions=("shrink", "preempt"))
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):   # both surfaces at once is an error
            ClusterScheduler(n_pods=1, elastic=True,
                             spec=PolicySpec(actions=("shrink",)))
    # the new surface alone is warning-free
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        ClusterScheduler(n_pods=1, spec=PolicySpec(actions=("shrink",)))


def test_star_import_clean_under_deprecation_errors():
    # the satellite contract: the re-exported surface itself must not
    # touch any deprecated path at import time
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c",
         "from repro.cluster import *"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_boolean_shim_equivalent_to_spec_on_showcases():
    with pytest.warns(DeprecationWarning):
        shim = ClusterScheduler(n_pods=1, policy="frag_repack",
                                horizon_s=3000.0, elastic=True)
    m_shim = shim.run(elastic_showcase())[1]
    m_spec = ClusterScheduler(
        n_pods=1, policy="frag_repack", horizon_s=3000.0,
        spec=PolicySpec(actions=("shrink",))).run(elastic_showcase())[1]
    assert m_shim == m_spec


def test_frozen_golden_identical_under_equivalent_policy_spec():
    # the PR 2/3/4 golden contract holds for BOTH compat surfaces: the
    # boolean shims (test_frozen_durations_bit_identical_to_pr2_scheduler
    # covers defaults) and the explicit empty PolicySpec
    trace = generate_trace(TraceConfig(**_PR2_TRACE))
    records, m = ClusterScheduler(n_pods=1, policy="frag_repack",
                                  frozen_durations=True,
                                  spec=PolicySpec()).run(trace)
    for key, want in _PR2_GOLDEN.items():
        assert getattr(m, key) == want, key
    timeline = repr([(r.job.job_id, r.place_s, r.finish_s) for r in records])
    assert (hashlib.sha256(timeline.encode()).hexdigest()
            == _PR2_TIMELINE_SHA)


def test_rescue_selection_not_hardcoded_in_scheduler():
    # the acceptance grep: all rescue selection lives in actions.py/policies
    import inspect
    from repro.cluster import scheduler as sched_mod
    src = inspect.getsource(sched_mod)
    for pattern in ("if self.elastic", "if self.priorities", "if self.grow"):
        assert pattern not in src


# ---------------------------------------------------------------------------
# cross-pod migration (MigrateAcrossPods: DCN-priced relocation)
# ---------------------------------------------------------------------------
def _run_migration(migrate):
    spec = PolicySpec(actions=("shrink", "preempt", "migrate") if migrate
                      else ("shrink", "preempt"))
    sched = ClusterScheduler(n_pods=2, policy="frag_repack", spec=spec)
    records, metrics = sched.run(migration_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 3)
    victim = next(r for r in records if r.job.job_id == 0)
    return sched, metrics, deadline_job, victim


def test_without_migrate_deadline_job_misses_slo():
    # the load imbalance: pod 1's free half is power-blocked for the hot
    # arrival, pod 0 is full, and every holder is a training job — no
    # shrink/preempt victim exists, so greedy in-pod rescues all fail
    _, metrics, deadline_job, victim = _run_migration(False)
    assert metrics.migrations == 0 and metrics.preemptions == 0
    assert metrics.shrinks == 0
    assert metrics.power_deferrals == 1
    assert deadline_job.place_s == pytest.approx(10_000.0)  # waited out
    assert deadline_job.finish_s > deadline_job.deadline_s
    assert victim.pod_idx == 0 and victim.migrations == 0


def test_migrate_turns_slo_miss_into_hit():
    sched, metrics, deadline_job, victim = _run_migration(True)
    assert metrics.migrations == 1 and metrics.power_deferrals == 0
    assert metrics.preemptions == 0 and metrics.shrinks == 0
    # the cold victim relocated to the hot pod; the hot arrival took its
    # drained rectangle on the cold pod — hot/cold balanced per pod
    assert victim.pod_idx == 1 and victim.migrations == 1
    assert victim.migrate_s == pytest.approx(10.0)
    assert deadline_job.pod_idx == 0
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finished
    assert deadline_job.finish_s <= deadline_job.deadline_s
    for pod in sched.pods:
        pod.partitioner.validate()


def test_migrate_priced_over_dcn_not_host_links():
    sched, metrics, deadline_job, victim = _run_migration(True)
    # the DCN term: volume = the victim's resident bytes, once across the
    # fabric; save_s = restore_s = bytes / PodSpec.dcn_bw
    assert metrics.dcn_migrated_bytes == victim.dcn_bytes > 0
    assert sched._dcn_bw == V5E_POD.dcn_bw
    assert V5E_POD.dcn_bw == pytest.approx(32 * 12.5e9)
    save_s = metrics.dcn_migrated_bytes / sched._dcn_bw
    assert metrics.dcn_migration_s == pytest.approx(2 * save_s)
    assert victim.dcn_delay_s == pytest.approx(2 * save_s)
    # the beneficiary starts after the victim's state drained (save_s)
    assert deadline_job.finish_s == pytest.approx(
        10.0 + save_s + deadline_job.job.duration_s)
    # the victim never suspended: it pays save+restore plus nothing else
    assert victim.preemptions == 0 and victim.suspended is None
    assert victim.finish_s == pytest.approx(
        10.0 + 2 * save_s + (victim.job.duration_s - 10.0))
    # in-pod migration counters stay untouched — different price basis
    assert metrics.migrated_bytes == 0 and metrics.migration_s == 0.0
    # DCN is meaningfully slower than the pod's aggregate host links
    assert V5E_POD.dcn_bw < sched._pod_host_bw


def test_migrate_requires_strictly_lower_priority():
    from dataclasses import replace
    jobs = [j if j.job_id != 0 else replace(j, priority=2)
            for j in migration_showcase()]
    jobs = [j if j.job_id != 2 else replace(j, priority=2) for j in jobs]
    sched = ClusterScheduler(n_pods=2, policy="frag_repack",
                             spec=PolicySpec(actions=("migrate",)))
    records, metrics = sched.run(jobs)
    assert metrics.migrations == 0
    deadline_job = next(r for r in records if r.job.job_id == 3)
    assert deadline_job.finish_s > deadline_job.deadline_s


def test_migrate_needs_two_pods():
    # the same stream collapsed onto one pod can never migrate
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             spec=PolicySpec(actions=("migrate",)))
    _, metrics = sched.run(lookahead_showcase())
    assert metrics.migrations == 0


def test_migrated_progress_job_keeps_work_done():
    # a progress-based victim (steps, not pinned duration) must carry its
    # nominal work across the pods: total wall = nominal + save/restore
    from repro.cluster.trace import _steps_for
    jobs = migration_showcase()
    victim_steps = _steps_for("llama3-8b", "train_4k", "8s.128c", 10_000.0)
    from dataclasses import replace
    jobs[0] = replace(jobs[0], duration_s=None, steps=victim_steps,
                      u_compute=0.2)
    sched = ClusterScheduler(n_pods=2, policy="frag_repack",
                             spec=PolicySpec(actions=("migrate",)))
    records, metrics = sched.run(jobs)
    victim = next(r for r in records if r.job.job_id == 0)
    assert metrics.migrations == 1 and victim.finished
    nominal = victim.job.steps * victim.step_time_s
    assert victim.finish_s == pytest.approx(
        victim.job.arrival_s + nominal + victim.dcn_delay_s)


# ---------------------------------------------------------------------------
# look-ahead policy (two-action chains)
# ---------------------------------------------------------------------------
def _run_lookahead(selector):
    spec = PolicySpec(selector=selector, actions=("shrink", "preempt"))
    sched = ClusterScheduler(n_pods=1, policy="frag_repack", spec=spec)
    records, metrics = sched.run(lookahead_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 3)
    return sched, metrics, records, deadline_job


def test_greedy_cannot_rescue_two_blocker_trace():
    # evicting either 8x8 batch job alone mints no 8x16 origin
    _, metrics, _, deadline_job = _run_lookahead("greedy")
    assert metrics.preemptions == 0 and metrics.shrinks == 0
    assert deadline_job.place_s > deadline_job.deadline_s


def test_lookahead_chains_two_evictions_and_hits_slo():
    sched, metrics, records, deadline_job = _run_lookahead("lookahead")
    assert metrics.preemptions == 2 and metrics.resumes == 2
    assert deadline_job.place_s == pytest.approx(10.0)
    assert deadline_job.finished
    assert deadline_job.finish_s <= deadline_job.deadline_s
    # both victims were evicted, later resumed, and completed
    for vid in (0, 1):
        victim = next(r for r in records if r.job.job_id == vid)
        assert victim.preemptions == 1 and victim.resumes == 1
        assert victim.finished
    # BOTH checkpoint drains delay the beneficiary (save of each victim)
    v0 = next(r for r in records if r.job.job_id == 0)
    v1 = next(r for r in records if r.job.job_id == 1)
    save_each = v0.checkpoint_bytes / 2 / sched._pod_host_bw
    assert v0.checkpoint_bytes == v1.checkpoint_bytes
    assert deadline_job.finish_s == pytest.approx(
        10.0 + 2 * save_each + deadline_job.job.duration_s)
    assert metrics.completed == 4
    sched.pods[0].partitioner.validate()


def test_lookahead_rollback_leaves_no_trace_when_chain_fails():
    # deadline slack (~0.2 s) above ONE checkpoint drain (~0.15 s) but
    # below two: each enabler trial-applies, its closer fails the SLO
    # check, and the rollback must leave the run indistinguishable from
    # the greedy one
    from dataclasses import replace
    jobs = [j if j.job_id != 3 else replace(j, slo_factor=1.0005)
            for j in lookahead_showcase()]
    m_greedy = ClusterScheduler(
        n_pods=1, policy="frag_repack",
        spec=PolicySpec(selector="greedy",
                        actions=("shrink", "preempt"))).run(jobs)[1]
    m_look = ClusterScheduler(
        n_pods=1, policy="frag_repack",
        spec=PolicySpec(selector="lookahead",
                        actions=("shrink", "preempt"))).run(jobs)[1]
    assert m_look.preemptions == 0 and m_look.resumes == 0
    assert m_look == m_greedy


def test_lookahead_single_action_path_matches_greedy():
    # when one action suffices, the look-ahead must commit exactly the
    # greedy plan (its chaining only engages on greedy failure)
    m_greedy = ClusterScheduler(
        n_pods=1, policy="frag_repack",
        spec=PolicySpec(selector="greedy",
                        actions=("shrink", "preempt"))).run(
        preemption_showcase())[1]
    m_look = ClusterScheduler(
        n_pods=1, policy="frag_repack",
        spec=PolicySpec(selector="lookahead",
                        actions=("shrink", "preempt"))).run(
        preemption_showcase())[1]
    assert m_greedy == m_look
    assert m_look.preemptions == 1


def test_lookahead_chains_grow_after_preempt():
    # a single preempt rescues the arrival; with the look-ahead policy a
    # running neighbour absorbs the leftover free rectangle in the same
    # event instead of waiting for the next completion
    from repro.cluster.trace import _steps_for
    jobs = [
        Job(job_id=0, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=0.0, profile="4s.64c", u_compute=0.3, priority=1,
            steps=_steps_for("llama3-8b", "train_4k", "4s.64c", 2_000.0)),
        Job(job_id=1, kind=BATCH, arch="gpt2-124m", shape="decode_32k",
            arrival_s=0.0, steps=1, profile="8s.128c",
            duration_s=10_000.0, u_compute=0.05, priority=0),
        Job(job_id=2, kind=TRAINING, arch="llama3-8b", shape="train_4k",
            arrival_s=10.0, steps=1, profile="8s.128c", duration_s=400.0,
            u_compute=0.3, priority=2, slo_factor=2.0),
    ]
    finishes = {}
    for selector in ("greedy", "lookahead"):
        sched = ClusterScheduler(
            n_pods=1, policy="frag_repack",
            spec=PolicySpec(selector=selector,
                            actions=("preempt", "grow")))
        records, metrics = sched.run(jobs)
        grower = next(r for r in records if r.job.job_id == 0)
        assert metrics.preemptions == 1 and metrics.grows == 1
        assert grower.grown and grower.profile_name == "8s.128c"
        finishes[selector] = grower.finish_s
    # the chained grow fires at the rescue (t=10), not at the first
    # completion (t≈410) — the grower finishes strictly earlier
    assert finishes["lookahead"] < finishes["greedy"]


# ---------------------------------------------------------------------------
# live SliceRuntime execution of serving jobs
# ---------------------------------------------------------------------------
def test_serving_jobs_execute_on_live_runtime():
    jobs = [
        Job(0, SERVING, "gpt2-124m", "decode_32k", 0.0, 50, requests=2),
        Job(1, BATCH, "mamba2-130m", "decode_32k", 5.0, 50, u_compute=0.1),
    ]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             execute_serving=True)
    records, metrics = sched.run(jobs)
    serving = next(r for r in records if r.job.kind == SERVING)
    assert serving.executed and serving.tokens_out > 0
    batch = next(r for r in records if r.job.kind == BATCH)
    assert not batch.executed
    assert metrics.completed == 2
    # tenant removed and rectangle released at completion
    pod = sched.pods[0]
    assert not pod.runtime.tenants
    assert pod.partitioner.free_chips() == V5E_POD.n_chips


# ---------------------------------------------------------------------------
# metrics table at fleet scale (ISSUE 6 small fix)
# ---------------------------------------------------------------------------
def test_format_metrics_separates_thousands_and_stays_aligned():
    from repro.cluster import ClusterMetrics, format_metrics
    m = ClusterMetrics(
        policy="frag_repack", n_jobs=1_269_134, placed=1_234_567,
        completed=1_200_000, left_queued=34_567, still_running=34_567,
        makespan_s=1_196_063.29, mean_queue_delay_s=12.5,
        p95_queue_delay_s=99.9, slo_attainment=0.97,
        chip_hour_utilization=0.55, frag_time_avg=0.123,
        energy_J=4.2e12, energy_per_chip_hour_kJ=1234.5,
        repacks=1_000_001, repack_failures=7, shrinks=2_500_000,
        grows=1_000, preemptions=3_000_000, resumes=2_999_999,
        wasted_checkpoint_chip_s=1e7, migrated_bytes=5 * 2**40,
        migration_s=1e5, migrations=1_234_567,
        dcn_migrated_bytes=2**41, dcn_migration_s=2e5,
        power_deferrals=9_999_999)
    table = format_metrics([m, m])
    lines = table.splitlines()
    # the grid must not misalign once counters run past six digits
    assert len({len(line) for line in lines}) == 1
    assert "1,234,567/1,200,000/34,567" in table
    assert "(+34,567 running at horizon)" in table
    assert "1,000,001/7" in table          # repacks ok/failed
    assert "2,500,000/1,000" in table      # shrinks/grows
    assert "3,000,000/2,999,999" in table  # preemptions/resumes
    assert "1,234,567 moves" in table      # cross-pod DCN migrations
    assert "9,999,999" in table            # power-deferred jobs
