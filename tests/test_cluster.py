"""ClusterScheduler stack: trace determinism, MISO-style placement,
fragmentation stranding + repack recovery (the bench_cluster scenario),
modeled migration cost, power-cap admission, live SliceRuntime execution,
and metrics sanity."""
from collections import Counter

import numpy as np
import pytest

from repro.cluster import (ClusterScheduler, TraceConfig,
                           fragmentation_showcase, generate_trace)
from repro.cluster.placement import (FirstFitPolicy, FragAwarePolicy,
                                     feasible_options, get_policy)
from repro.cluster.trace import BATCH, KINDS, SERVING, TRAINING, Job
from repro.core.hw import V5E_POD


# ---------------------------------------------------------------------------
# trace generator
# ---------------------------------------------------------------------------
def test_trace_deterministic_and_mixed():
    a = generate_trace(TraceConfig(seed=3))
    b = generate_trace(TraceConfig(seed=3))
    assert a == b
    assert a != generate_trace(TraceConfig(seed=4))
    kinds = Counter(j.kind for j in a)
    assert set(kinds) <= set(KINDS) and len(kinds) == 3
    arrivals = [j.arrival_s for j in a]
    assert arrivals == sorted(arrivals)
    assert all(j.requests > 0 for j in a if j.kind == SERVING)
    assert all(j.u_compute is not None and j.u_compute < 0.2
               for j in a if j.kind == BATCH)


def test_feasible_options_pinned_profile():
    job = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10,
              profile="4s.64c")
    opts = feasible_options(job)
    assert [p.name for p, _, _ in opts] == ["4s.64c"]
    free = Job(0, TRAINING, "llama3-8b", "train_4k", 0.0, 10)
    assert len(feasible_options(free)) > 1


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
def test_first_fit_takes_smallest_feasible():
    sched = ClusterScheduler(n_pods=1, policy="first_fit")
    job = Job(0, SERVING, "llama3-8b", "decode_32k", 0.0, 100)
    cands = sched.policy.candidates(job, sched.pods, sched.chip, 0.0, None)
    smallest = feasible_options(job)[0][0]
    assert cands[0].profile.name == smallest.name
    assert cands[0].origin == (0, 0)


def test_frag_aware_candidates_sorted_and_scored():
    sched = ClusterScheduler(n_pods=2, policy="frag")
    job = Job(0, TRAINING, "qwen3-32b", "train_4k", 0.0, 20)
    cands = sched.policy.candidates(job, sched.pods, sched.chip, 0.0, None)
    assert cands, "empty cluster must offer candidates"
    flags = [c.meets_deadline for c in cands]
    assert flags == sorted(flags, reverse=True)
    for c in cands:
        assert c.perf_per_chip > 0
        assert c.largest_after >= 0


def test_get_policy_unknown():
    with pytest.raises(KeyError):
        get_policy("optimal")


# ---------------------------------------------------------------------------
# the stranding scenario (acceptance criterion: repack places a job
# first-fit leaves queued, on the same deterministic trace)
# ---------------------------------------------------------------------------
STRANDED = 10


def _run_showcase(policy):
    sched = ClusterScheduler(n_pods=1, policy=policy, horizon_s=3000.0)
    records, metrics = sched.run(fragmentation_showcase())
    big = next(r for r in records if r.job.job_id == STRANDED)
    return sched, records, metrics, big


def test_first_fit_strands_big_job():
    _, _, metrics, big = _run_showcase("first_fit")
    assert not big.placed, "first-fit should leave the 8x16 job queued"
    assert metrics.left_queued == 1
    assert metrics.repacks == 0
    assert metrics.frag_time_avg > 0.3  # scattered holes persist


def test_repack_places_stranded_job_with_migration_cost():
    sched, records, metrics, big = _run_showcase("frag_repack")
    assert big.placed and big.finished
    assert big.profile_name == "8s.128c"
    assert metrics.left_queued == 0
    assert metrics.repacks == 1 and metrics.repack_failures == 0
    assert metrics.migrated_bytes > 0
    assert metrics.migration_s == pytest.approx(
        metrics.migrated_bytes / sched._pod_host_bw)
    # the stranded job starts only after the migration delay
    assert big.finish_s > big.place_s + big.job.duration_s
    # defrag is visible in the time-averaged fragmentation ratio
    assert metrics.frag_time_avg < 0.05
    sched.pods[0].partitioner.validate()


def test_repack_stretches_moved_running_jobs():
    _, records, _, _ = _run_showcase("frag_repack")
    moved_long = [r for r in records
                  if r.job.duration_s == 10_000.0 and r.placed]
    assert moved_long, "long jobs should be running when repack fires"
    stretched = [r for r in moved_long
                 if r.finish_s > r.place_s + r.job.duration_s]
    assert stretched, "migration must delay at least one moved running job"


# ---------------------------------------------------------------------------
# power-cap admission (paper §V-B)
# ---------------------------------------------------------------------------
def _hot_job(jid, arrival, duration):
    return Job(jid, TRAINING, "llama3-8b", "train_4k", arrival, 1,
               profile="8s.128c", duration_s=duration, u_compute=1.0)


def test_power_cap_defers_second_hot_job():
    # two full-power 128-chip jobs together draw 51.2 kW > the 43.5 kW cap
    # (throttle 0.79 < 0.8) -> the second waits for the first to finish
    jobs = [_hot_job(0, 0.0, 100.0), _hot_job(1, 1.0, 100.0)]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             min_throttle=0.8)
    records, metrics = sched.run(jobs)
    second = next(r for r in records if r.job.job_id == 1)
    assert metrics.power_deferrals >= 1
    assert second.place_s == pytest.approx(100.0)  # admitted at completion
    # with the gate off, both co-run and the pod throttles instead
    sched2 = ClusterScheduler(n_pods=1, policy="frag_repack",
                              min_throttle=0.0)
    records2, metrics2 = sched2.run(jobs)
    second2 = next(r for r in records2 if r.job.job_id == 1)
    assert metrics2.power_deferrals == 0
    assert second2.place_s == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# end-to-end on a generated trace
# ---------------------------------------------------------------------------
def test_scheduler_deterministic_and_metrics_sane():
    trace = generate_trace(TraceConfig(seed=0, n_jobs=16))
    m1 = ClusterScheduler(n_pods=2, policy="frag_repack").run(trace)[1]
    m2 = ClusterScheduler(n_pods=2, policy="frag_repack").run(trace)[1]
    assert m1 == m2
    assert m1.placed == m1.n_jobs == 16
    assert m1.completed == 16 and m1.still_running == 0
    assert 0.0 < m1.chip_hour_utilization <= 1.0
    assert 0.0 <= m1.slo_attainment <= 1.0
    assert 0.0 <= m1.frag_time_avg <= 1.0
    assert m1.energy_J > 0 and m1.makespan_s > 0


def test_pods_empty_after_drain():
    trace = generate_trace(TraceConfig(seed=1, n_jobs=10))
    sched = ClusterScheduler(n_pods=2, policy="frag")
    sched.run(trace)
    for pod in sched.pods:
        assert pod.partitioner.free_chips() == V5E_POD.n_chips
        assert not pod.jobs and not pod.slice_jobs
        pod.partitioner.validate()


def test_scheduler_single_use():
    sched = ClusterScheduler(n_pods=1)
    sched.run([])
    with pytest.raises(AssertionError):
        sched.run([])


# ---------------------------------------------------------------------------
# live SliceRuntime execution of serving jobs
# ---------------------------------------------------------------------------
def test_serving_jobs_execute_on_live_runtime():
    jobs = [
        Job(0, SERVING, "gpt2-124m", "decode_32k", 0.0, 50, requests=2),
        Job(1, BATCH, "mamba2-130m", "decode_32k", 5.0, 50, u_compute=0.1),
    ]
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             execute_serving=True)
    records, metrics = sched.run(jobs)
    serving = next(r for r in records if r.job.kind == SERVING)
    assert serving.executed and serving.tokens_out > 0
    batch = next(r for r in records if r.job.kind == BATCH)
    assert not batch.executed
    assert metrics.completed == 2
    # tenant removed and rectangle released at completion
    pod = sched.pods[0]
    assert not pod.runtime.tenants
    assert pod.partitioner.free_chips() == V5E_POD.n_chips
