"""SliceRuntime stack: multi-tenant packing, per-tenant offload plans cut
from real inventories, engine equivalence under offload, truncation
recording, admission control, partitioner repack, and partial-spill
placement rounding."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hw import V5E_POD
from repro.core.offload import (OffloadPlan, device_memory_kind,
                                host_memory_kind, plan_offload,
                                shardings_with_offload)
from repro.core.partitioner import StaticPartitioner
from repro.core.slices import get_profile
from repro.launch.mesh import make_host_mesh
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.serving import (KVPool, Request, ServingEngine, SliceRuntime,
                           TenantEngine, TenantSpec)

ENV = host_axis_env()


@pytest.fixture(scope="module")
def gpt2():
    cfg = get_config("gpt2-124m").reduced().with_(remat="none")
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def _partial_kv_plan(model, params, slots, max_seq):
    """A plan whose overhang lands inside a divisible KV leaf."""
    cache = model.init_cache(slots, max_seq)
    inv = model.serving_inventory(params, cache)
    total = sum(t.bytes for t in inv)
    embed = sum(t.bytes for t in inv if t.group == "embed")
    kv = sum(t.bytes for t in inv if t.group == "kv_cache")
    plan = plan_offload(inv, total - embed - kv // 4, spill_granule=1024)
    assert plan.partial, "test setup: expected a partial spill"
    return plan


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------
def test_packing_fails_loudly(gpt2):
    cfg, _, _ = gpt2
    rt = SliceRuntime()
    rt.add_tenant(TenantSpec("big", cfg, profile="16s.256c",
                             slots=1, max_seq=16))
    free_before = rt.partitioner.free_chips()
    with pytest.raises(RuntimeError, match="no room"):
        rt.add_tenant(TenantSpec("late", cfg, profile="1s.16c",
                                 slots=1, max_seq=16))
    # failed admission must not leak a slice or a tenant
    assert rt.partitioner.free_chips() == free_before
    assert "late" not in rt.tenants
    with pytest.raises(ValueError, match="duplicate"):
        rt.add_tenant(TenantSpec("big", cfg, profile="1s.16c",
                                 slots=1, max_seq=16))


def test_resize_tenant_grow_shrink_roundtrip(gpt2):
    cfg, _, _ = gpt2
    rt = SliceRuntime()
    tenant = rt.add_tenant(TenantSpec("t", cfg, profile="1s.16c",
                                      slots=1, max_seq=16))
    sid = tenant.alloc.slice_id
    origin = tenant.alloc.origin
    free0 = rt.partitioner.free_chips()
    grown = rt.resize_tenant("t", "4s.64c")
    assert grown is tenant and tenant.alloc.slice_id == sid
    assert tenant.alloc.profile.name == "4s.64c"
    assert rt.partitioner.free_chips() == free0 - (64 - 16)
    assert tenant.plan.fits
    rt.partitioner.validate()
    back = rt.resize_tenant("t", "1s.16c")
    assert back.alloc.profile.name == "1s.16c"
    assert back.alloc.origin == origin
    assert rt.partitioner.free_chips() == free0
    rt.partitioner.validate()
    # no-op resize returns the tenant untouched
    assert rt.resize_tenant("t", "1s.16c") is tenant


def test_resize_tenant_grow_conflict_is_transactional(gpt2):
    cfg, _, _ = gpt2
    rt = SliceRuntime()
    rt.add_tenant(TenantSpec("a", cfg, profile="1s.16c", slots=1,
                             max_seq=16))         # origin (0,0)
    rt.add_tenant(TenantSpec("b", cfg, profile="1s.16c", slots=1,
                             max_seq=16,
                             origin=(0, 4)))      # blocks a's 4x8 extension
    a = rt.tenants["a"]
    plan_before = a.plan
    grid_before = rt.partitioner._grid.copy()
    with pytest.raises(RuntimeError, match="extend failed"):
        rt.resize_tenant("a", "2s.32c")
    assert (rt.partitioner._grid == grid_before).all()
    assert a.alloc.profile.name == "1s.16c" and a.plan is plan_before
    rt.partitioner.validate()


def test_resize_tenant_probe_rejects_unfit_profile(gpt2, monkeypatch):
    cfg, _, _ = gpt2
    rt = SliceRuntime()
    tenant = rt.add_tenant(TenantSpec("t", cfg, profile="2s.32c", slots=1,
                                      max_seq=16))
    plan_before = tenant.plan
    grid_before = rt.partitioner._grid.copy()
    # the plan probe reports the new profile cannot hold the tenant: the
    # resize must fail BEFORE the rectangle moves (probe → commit order)
    import repro.serving.runtime as runtime_mod
    unfit = dataclasses.replace(plan_before, fits=False)
    monkeypatch.setattr(runtime_mod, "plan_offload",
                        lambda *a, **k: unfit)
    with pytest.raises(RuntimeError, match="does not fit"):
        rt.resize_tenant("t", "1s.16c")
    assert (rt.partitioner._grid == grid_before).all()
    assert tenant.alloc.profile.name == "2s.32c"
    assert tenant.plan is plan_before


def test_partitioner_repack_defragments():
    part = StaticPartitioner()
    p = get_profile("1s.16c")
    allocs = [part.allocate(p, tag=f"t{i}") for i in range(4)]
    part.release(allocs[0].slice_id)
    part.release(allocs[2].slice_id)
    moved = part.repack()
    part.validate()
    # survivors compacted to the lowest-aligned origins
    origins = sorted(a.origin for a in part.allocations.values())
    assert origins == [(0, 0), (0, 4)]
    assert set(moved) <= {a.slice_id for a in allocs}
    assert part.free_chips() == V5E_POD.n_chips - 2 * p.n_chips


def test_repack_preserves_dead_chips():
    part = StaticPartitioner()
    a = part.allocate(get_profile("1s.16c"), tag="victim")
    part.fail_chips([(0, 0)])          # kills the slice, marks chip dead
    assert a.slice_id not in part.allocations
    b = part.allocate(get_profile("1s.16c"), tag="evacuee")
    part.repack()
    part.validate()
    # dead chip's aligned rectangle cannot host the survivor
    assert part.allocations[b.slice_id].origin != (0, 0)


def test_repack_rolls_back_when_replacement_fails(monkeypatch):
    part = StaticPartitioner()
    for _ in range(3):
        part.allocate(get_profile("1s.16c"))
    part.release(1)  # leave a hole so repack has something to move
    grid_before = part._grid.copy()
    origins_before = {sid: a.origin for sid, a in part.allocations.items()}
    original = StaticPartitioner._find_origin
    calls = {"n": 0}

    def flaky(self, profile):
        calls["n"] += 1
        return None if calls["n"] >= 2 else original(self, profile)

    monkeypatch.setattr(StaticPartitioner, "_find_origin", flaky)
    with pytest.raises(RuntimeError, match="repack failed"):
        part.repack()
    monkeypatch.setattr(StaticPartitioner, "_find_origin", original)
    # full rollback: grid and every allocation origin untouched
    assert (part._grid == grid_before).all()
    assert {sid: a.origin
            for sid, a in part.allocations.items()} == origins_before
    part.validate()


def test_allocate_at_pinned_origin():
    part = StaticPartitioner()
    p = get_profile("1s.16c")
    a = part.allocate(p, origin=(4, 8))
    assert a.origin == (4, 8)
    with pytest.raises(RuntimeError, match="not free"):
        part.allocate(p, origin=(4, 8))
    with pytest.raises(ValueError, match="not aligned"):
        part.allocate(p, origin=(2, 8))
    assert (4, 8) not in part.origins_for(p)
    part.validate()


def test_spilled_fraction_is_a_true_fraction():
    """Pins the fixed semantics: partial entries report spilled/total in
    [0,1] (previously they leaked raw spilled *bytes*)."""
    GiB = 1024 ** 3
    from repro.core.offload import TensorInfo
    inv = [TensorInfo("cold", 2 * GiB, "kv_cache", traffic_multiplier=0.05),
           TensorInfo("warm", 8 * GiB, "kv_cache", divisible=True,
                      traffic_multiplier=2.0),
           TensorInfo("stays", 1 * GiB, "param")]
    plan = plan_offload(inv, 6 * GiB)
    assert plan.fits
    assert plan.spilled_fraction("cold") == 1.0
    assert plan.spilled_fraction("stays") == 0.0
    spilled = dict(plan.partial)["warm"]
    assert 0 < spilled < 8 * GiB
    assert plan.spilled_fraction("warm") == pytest.approx(
        spilled / (8 * GiB))
    assert 0.0 < plan.spilled_fraction("warm") < 1.0
    # caller-supplied total overrides the recorded one
    assert plan.spilled_fraction("warm", total_bytes=spilled) == 1.0
    # hand-built plans without recorded totals must demand one
    bare = OffloadPlan((), (("x", 7),), 0, 7, 0.0, True)
    with pytest.raises(ValueError):
        bare.spilled_fraction("x")
    assert bare.spilled_fraction("x", total_bytes=14) == 0.5


# ---------------------------------------------------------------------------
# plans vs inventory
# ---------------------------------------------------------------------------
def test_tenant_plans_match_inventory(gpt2, mesh):
    cfg, model, params = gpt2
    rt = SliceRuntime(mesh=mesh)
    cache = model.init_cache(2, 32)
    inv = model.serving_inventory(params, cache)
    total = sum(t.bytes for t in inv)
    names = {t.name for t in inv}

    fits = rt.add_tenant(TenantSpec("fits", cfg, profile="1s.16c",
                                    slots=2, max_seq=32))
    spilled = rt.add_tenant(TenantSpec(
        "spilled", cfg, profile="1s.16c", slots=2, max_seq=32,
        hbm_budget=int(total * 0.8), spill_granule=1024))

    # plan conservation: every byte is either resident or on the host
    for t in (fits, spilled):
        assert t.plan.resident_bytes + t.plan.host_bytes == total
        assert set(t.plan.offloaded) <= names
        assert {n for n, _ in t.plan.partial} <= names
    assert fits.plan.host_bytes == 0 and not fits.plan.offloaded
    assert spilled.plan.host_bytes > 0
    assert spilled.plan.resident_bytes <= int(total * 0.8)
    # the engine's pool accounts for every cache byte, wherever it lives
    pool = spilled.engine.pool
    assert pool.host_bytes + pool.device_bytes == model.cache_bytes(2, 32)


# ---------------------------------------------------------------------------
# engine equivalence + truncation + admission
# ---------------------------------------------------------------------------
def test_engine_equivalence_offload_on_off(gpt2, mesh):
    cfg, model, params = gpt2
    prompts = [np.arange(2, 8, dtype=np.int32) % cfg.vocab_size,
               np.arange(5, 14, dtype=np.int32) % cfg.vocab_size]
    reqs = lambda: [Request(i, p, 5) for i, p in enumerate(prompts)]  # noqa: E731

    base = ServingEngine(model, params, slots=2, max_seq=48).run(reqs())
    full_off = ServingEngine(model, params, slots=2, max_seq=48,
                             mesh=mesh, offload_kv=True).run(reqs())
    assert base == full_off

    plan = _partial_kv_plan(model, params, 2, 48)
    eng = TenantEngine(model, params, slots=2, max_seq=48, mesh=mesh,
                       plan=plan)
    assert eng.pool.split_leaves, "partial plan must split a kv leaf"
    assert eng.pool.host_bytes > 0 and eng.pool.device_bytes > 0
    assert base == eng.run(reqs())


def test_eviction_records_partial_generation(gpt2):
    cfg, model, params = gpt2
    prompt = np.arange(1, 9, dtype=np.int32) % cfg.vocab_size
    eng = ServingEngine(model, params, slots=1, max_seq=16)
    # wants 50 tokens but the slot caps at max_seq: evicted after ~7
    out = eng.run([Request(0, prompt, 50)])
    assert 0 in out, "evicted request must still be reported"
    assert 0 < len(out[0]) < 50
    assert eng.stats.truncated == 1
    # and the engine kept serving afterwards (slot recycled)
    out2 = eng.run([Request(1, prompt, 3)])
    assert len(out2[1]) == 3


def test_overlong_prompt_rejected_not_crashed(gpt2):
    cfg, model, params = gpt2
    eng = ServingEngine(model, params, slots=1, max_seq=8)
    long_prompt = np.arange(1, 13, dtype=np.int32) % cfg.vocab_size  # 12 > 7
    ok_prompt = np.arange(1, 5, dtype=np.int32) % cfg.vocab_size
    out = eng.run([Request(0, long_prompt, 4), Request(1, ok_prompt, 3)])
    assert out[0] == [] and eng.stats.rejected == 1
    assert len(out[1]) == 3


def test_admission_control_bounds_queue(gpt2):
    cfg, model, params = gpt2
    eng = TenantEngine(model, params, slots=1, max_seq=32, max_queue=2)
    prompt = np.arange(1, 5, dtype=np.int32) % cfg.vocab_size
    accepted = [eng.submit(Request(i, prompt, 2)) for i in range(5)]
    assert accepted == [True, True, False, False, False]
    assert eng.stats.rejected == 3
    while not eng.idle:
        eng.tick()
    assert set(eng.outputs) == {0, 1}


# ---------------------------------------------------------------------------
# runtime end-to-end
# ---------------------------------------------------------------------------
def test_runtime_serves_tenants_concurrently(gpt2, mesh):
    cfg, model, params = gpt2
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(3)]

    # reference: the same requests through a lone engine
    want = ServingEngine(model, params, slots=2, max_seq=32).run(
        [Request(i, p, 4) for i, p in enumerate(prompts)])

    rt = SliceRuntime(mesh=mesh)
    rt.add_tenant(TenantSpec("a", cfg, profile="1s.16c", slots=2, max_seq=32))
    rt.add_tenant(TenantSpec("b", cfg, profile="2s.32c", slots=2, max_seq=32,
                             seed=1))
    rt.submit("a", [Request(i, p, 4) for i, p in enumerate(prompts)])
    rt.submit("b", [Request(i, p, 4) for i, p in enumerate(prompts)])
    report = rt.run()

    assert rt.tenants["a"].engine.outputs == want, \
        "co-running another tenant must not change tenant a's tokens"
    for name in ("a", "b"):
        row = report["tenants"][name]
        assert row["tokens_out"] == 12 and row["completed"] == 3
        # per-tenant latency percentiles surface through the report
        lat = row["latency"]
        assert set(lat) == {"queue_wait_p50", "queue_wait_p99",
                            "e2e_p50", "e2e_p99"}
        assert lat["e2e_p99"] >= lat["e2e_p50"] > 0.0
    assert report["pod_utilization"] == pytest.approx(48 / 256)
    assert 0 < report["modeled"]["throttle"] <= 1.0
    # release + repack path
    rt.remove_tenant("a", repack=True)
    assert report["pod_utilization"] > rt.partitioner.utilization()


def test_report_twin_block_gated_on_perf_model(gpt2):
    # the per-tenant "twin" row surfaces only when the runtime's PerfModel
    # prices twin-offload rungs; the default model leaves the key out so
    # existing report consumers see an unchanged schema
    from repro.core.offload import TwinSpec
    from repro.core.perfmodel import get_model

    cfg, _, _ = gpt2
    rt = SliceRuntime()
    rt.add_tenant(TenantSpec("t", cfg, profile="1s.16c", slots=1, max_seq=16))
    assert "twin" not in rt.report()["tenants"]["t"]

    rt2 = SliceRuntime(perf=get_model(twin=TwinSpec()))
    rt2.add_tenant(TenantSpec("t", cfg, profile="1s.16c", slots=1, max_seq=16))
    row = rt2.report()["tenants"]["t"]
    assert "twin" in row
    # the reduced demo model fits its slice outright — nothing spills, so
    # no twin rung exists and the row says so explicitly rather than
    # omitting the key
    tw = row["twin"]
    assert tw is None or (
        "+cpu" in tw["rung"]
        and 0.0 < tw["cpu_fraction"] <= 1.0
        and tw["step_time_s"] > 0.0)


# ---------------------------------------------------------------------------
# placement rounding for partial spills
# ---------------------------------------------------------------------------
def test_shardings_with_offload_partial_rounding(mesh):
    from jax.sharding import PartitionSpec as P
    host_kind, dev_kind = host_memory_kind(mesh), device_memory_kind(mesh)
    specs = {"a": P(), "b": P(), "c": P()}
    sizes = {"a": 100, "b": 100, "c": 100}
    plan = OffloadPlan(offloaded=("a",), partial=(("b", 75), ("c", 25)),
                       resident_bytes=100, host_bytes=200,
                       host_traffic_per_step=0.0, fits=True)
    sh = shardings_with_offload(specs, plan, mesh, sizes=sizes)
    assert sh["a"].memory_kind == host_kind     # fully offloaded
    assert sh["b"].memory_kind == host_kind     # 75% spilled -> host side
    assert sh["c"].memory_kind == dev_kind      # 25% spilled -> device side
    # without sizes the fraction is unknowable -> partial stays on device
    sh2 = shardings_with_offload(specs, plan, mesh)
    assert sh2["b"].memory_kind == dev_kind


def test_kv_pool_slot_lifecycle(gpt2, mesh):
    cfg, model, params = gpt2
    pool = KVPool(model, slots=3, max_seq=16, mesh=mesh)
    slots = [pool.alloc_slot() for _ in range(3)]
    assert pool.alloc_slot() is None
    pool.free_slot(slots[1])
    assert pool.free_slots == 1
    assert pool.positions[slots[1]] == 0
