"""Property-based tests (hypothesis) on the paper-core invariants:
partitioner packing, shared-cap power throttling, offload-planner knapsack,
quantization, reward metric."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.hw import GiB, V5E_POD
from repro.core.offload import (MIN_SPILL_BYTES, OffloadPlan, TensorInfo,
                                plan_offload)
from repro.core.partitioner import StaticPartitioner
from repro.core.power import InstanceLoad, pod_draw, throttle_factor
from repro.core.slices import PROFILES, get_profile
from repro.optim.compression import compress_residual, dequantize_int8, quantize_int8


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------
profile_strategy = st.sampled_from([p.name for p in PROFILES])


@settings(max_examples=40, deadline=None)
@given(st.lists(profile_strategy, min_size=1, max_size=20))
def test_partitioner_never_overlaps(names):
    part = StaticPartitioner()
    allocated = []
    for name in names:
        try:
            allocated.append(part.allocate(get_profile(name)))
        except RuntimeError:
            break
    part.validate()  # raises on overlap / corruption
    assert part.used_chips() == sum(a.profile.n_chips for a in allocated)
    assert part.used_chips() + part.free_chips() == V5E_POD.n_chips


@settings(max_examples=25, deadline=None)
@given(st.lists(profile_strategy, min_size=2, max_size=10),
       st.data())
def test_partitioner_release_restores_capacity(names, data):
    part = StaticPartitioner()
    allocs = []
    for name in names:
        try:
            allocs.append(part.allocate(get_profile(name)))
        except RuntimeError:
            break
    if not allocs:
        return
    victim = data.draw(st.sampled_from(allocs))
    before = part.free_chips()
    part.release(victim.slice_id)
    part.validate()
    assert part.free_chips() == before + victim.profile.n_chips


def test_partitioner_full_pod_of_smallest():
    part = StaticPartitioner()
    prof = get_profile("1s.16c")
    for _ in range(prof.max_instances(V5E_POD)):
        part.allocate(prof)
    assert part.free_chips() == 0
    with pytest.raises(RuntimeError):
        part.allocate(prof)


def test_fail_chips_releases_and_marks_dead():
    part = StaticPartitioner()
    a = part.allocate(get_profile("8s.128c"))
    affected = part.fail_chips([(0, 0)])
    assert affected == [a.slice_id]
    part.validate()
    # dead chip cannot be reallocated into a slice covering it
    b = part.allocate(part.largest_free_profile())
    r, c, r2, c2 = b.rect
    assert not (r <= 0 < r2 and c <= 0 < c2)


def test_fail_chips_drops_cached_index_eagerly():
    # regression: fail_chips used to bump the generation directly instead
    # of routing through mark_dirty(), so a free-rectangle index built
    # *before* the failure stayed cached. A self-restoring probe trial
    # that later re-stamped the pre-failure generation via
    # restore_generation() would then serve the stale index — and offer
    # origins covering dead chips.
    part = StaticPartitioner()
    g = part.generation
    part._index()                        # build the lazy cache at gen g
    part.fail_chips([(0, 0)])
    assert part.generation != g          # failure is a grid mutation
    assert part._idx is None and part._idx_gen == -1   # dropped eagerly
    part.restore_generation(g)           # a trial re-stamp must not revive it
    assert part._idx is None
    # the full-pod profile covers the dead cell — no origin may exist
    assert part.origins_for(get_profile("16s.256c")) == []
    part.validate()


# ---------------------------------------------------------------------------
# repack (the defrag move behind repro.cluster's repack-enabled policy)
# ---------------------------------------------------------------------------
def _churned_partitioner(names, data):
    """Allocate a profile sequence, release a random subset, optionally kill
    random chips — the interleaved-lifetime state repack() exists for."""
    part = StaticPartitioner()
    for name in names:
        try:
            part.allocate(get_profile(name))
        except RuntimeError:
            break
    live = sorted(part.allocations)
    if live:
        victims = data.draw(st.lists(st.sampled_from(live), unique=True,
                                     max_size=len(live)))
        for sid in victims:
            part.release(sid)
    coords = data.draw(st.lists(
        st.tuples(st.integers(0, V5E_POD.rows - 1),
                  st.integers(0, V5E_POD.cols - 1)),
        unique=True, max_size=6))
    part.fail_chips(coords)
    return part


@settings(max_examples=40, deadline=None)
@given(st.lists(profile_strategy, min_size=1, max_size=14), st.data())
def test_repack_no_overlap_and_dead_chips_stay_dead(names, data):
    part = _churned_partitioner(names, data)
    grid_before = part._grid.copy()
    live_before = dict(part.allocations)
    try:
        part.repack()
    except RuntimeError:
        # failed repack must be a full rollback: grid untouched
        assert (part._grid == grid_before).all()
        assert part.allocations == live_before
        return
    part.validate()  # disjoint rectangles matching the grid marks
    assert set(part.allocations) == set(live_before)
    # dead chips never move, never get reused
    assert ((part._grid == -2) == (grid_before == -2)).all()
    for a in part.allocations.values():
        r, c, r2, c2 = a.rect
        assert (part._grid[r:r2, c:c2] != -2).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(profile_strategy, min_size=1, max_size=14), st.data())
def test_repack_never_shrinks_largest_placeable(names, data):
    part = _churned_partitioner(names, data)
    before = part.largest_free_profile()
    try:
        part.repack()
    except RuntimeError:
        return
    after = part.largest_free_profile()
    assert ((after.n_chips if after else 0)
            >= (before.n_chips if before else 0))


# (the deterministic rollback test lives in test_slice_runtime.py so it
# also runs where hypothesis is unavailable)


# ---------------------------------------------------------------------------
# extend (the elastic-grow primitive behind ClusterScheduler(grow=True));
# properties mirror the repack() suite above
# ---------------------------------------------------------------------------
def _alloc_signature(part):
    return {sid: (a.profile.name, a.origin)
            for sid, a in part.allocations.items()}


@settings(max_examples=40, deadline=None)
@given(st.lists(profile_strategy, min_size=1, max_size=14), st.data())
def test_extend_no_overlap_and_rollback_restores_state(names, data):
    part = _churned_partitioner(names, data)
    if not part.allocations:
        return
    sid = data.draw(st.sampled_from(sorted(part.allocations)))
    target = get_profile(data.draw(profile_strategy))
    grid_before = part._grid.copy()
    sig_before = _alloc_signature(part)
    old = part.allocations[sid]
    old_profile, (r0, c0) = old.profile, old.origin
    try:
        part.extend(sid, target)
    except (RuntimeError, ValueError):
        # failed extend is a full rollback: grid and table bit-identical
        assert (part._grid == grid_before).all()
        assert _alloc_signature(part) == sig_before
        return
    part.validate()  # disjoint rectangles matching the grid marks
    sig_after = _alloc_signature(part)
    # only the extended slice changed; every live neighbour is untouched
    assert set(sig_after) == set(sig_before)
    for s in sig_after:
        if s != sid:
            assert sig_after[s] == sig_before[s]
    assert sig_after[sid][0] == target.name
    # the old rectangle is contained in the new one (state stays local)
    nr, nc = part.allocations[sid].origin
    assert nr <= r0 and nc <= c0
    assert r0 + old_profile.rows <= nr + target.rows
    assert c0 + old_profile.cols <= nc + target.cols
    # dead chips are never absorbed and never move
    assert ((part._grid == -2) == (grid_before == -2)).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(profile_strategy, min_size=1, max_size=14), st.data())
def test_extend_then_shrink_roundtrips_profile(names, data):
    """Growing a slice and then shrinking it back (the scheduler's shrink
    move: release + re-allocate the original profile at the original
    origin) restores the exact free/occupied footprint."""
    part = _churned_partitioner(names, data)
    if not part.allocations:
        return
    sid = data.draw(st.sampled_from(sorted(part.allocations)))
    target = get_profile(data.draw(profile_strategy))
    free_before = (part._grid == -1).copy()
    old = part.allocations[sid]
    old_profile, old_origin = old.profile, old.origin
    try:
        part.extend(sid, target)
    except (RuntimeError, ValueError):
        return
    part.release(sid)
    back = part.allocate(old_profile, origin=old_origin)
    part.validate()
    assert back.profile is old_profile and back.origin == old_origin
    assert ((part._grid == -1) == free_before).all()


# ---------------------------------------------------------------------------
# power model (the §V-B shared-cap surface PerfModel/PodSimulator sit on)
# ---------------------------------------------------------------------------
instance_strategy = st.builds(
    InstanceLoad,
    n_chips=st.sampled_from([16, 32, 64, 128]),
    u_compute=st.floats(0.0, 1.0, allow_nan=False),
    step_time=st.floats(0.01, 100.0, allow_nan=False),
    steps=st.integers(1, 100),
)


def _fitting_mixes(instances):
    """Clip a drawn instance list to the pod's 256 chips."""
    out, used = [], 0
    for i in instances:
        if used + i.n_chips > V5E_POD.n_chips:
            break
        out.append(i)
        used += i.n_chips
    return out


@settings(max_examples=60, deadline=None)
@given(st.lists(instance_strategy, min_size=1, max_size=16), st.data())
def test_throttle_never_decreases_when_instance_removed(instances, data):
    mix = _fitting_mixes(instances)
    if not mix:
        return
    before = throttle_factor(mix, V5E_POD)
    victim = data.draw(st.integers(0, len(mix) - 1))
    after = throttle_factor(mix[:victim] + mix[victim + 1:], V5E_POD)
    # removing load can only relax the shared cap (f closer to 1)
    assert after >= before - 1e-12


@settings(max_examples=60, deadline=None)
@given(st.lists(instance_strategy, min_size=0, max_size=16))
def test_throttle_is_one_under_the_cap(instances):
    mix = _fitting_mixes(instances)
    if pod_draw(mix, V5E_POD) <= V5E_POD.power_cap_watts:
        assert throttle_factor(mix, V5E_POD) == 1.0


@settings(max_examples=60, deadline=None)
@given(st.lists(instance_strategy, min_size=1, max_size=16))
def test_throttled_implied_draw_respects_cap(instances):
    mix = _fitting_mixes(instances)
    if not mix:
        return
    f = throttle_factor(mix, V5E_POD)
    if f >= 1.0:
        return
    # dynamic power scales with f, idle cannot be throttled away
    idle_floor = V5E_POD.n_chips * V5E_POD.chip.idle_watts
    dynamic = pod_draw(mix, V5E_POD) - idle_floor
    implied = idle_floor + f * dynamic
    # f is floored at 0.1, so the implied draw may legitimately exceed the
    # cap only when even maximal throttling cannot get under it
    if f > 0.1:
        assert implied <= V5E_POD.power_cap_watts * (1 + 1e-9)


# ---------------------------------------------------------------------------
# offload planner
# ---------------------------------------------------------------------------
tensor_strategy = st.builds(
    TensorInfo,
    name=st.uuids().map(str),
    bytes=st.integers(1 * 1024 * 1024, 64 * GiB),
    group=st.sampled_from(["opt_state", "param", "embed", "kv_cache",
                           "activation"]),
    offloadable=st.booleans(),
    divisible=st.booleans(),
)


@settings(max_examples=60, deadline=None)
@given(st.lists(tensor_strategy, min_size=1, max_size=12),
       st.integers(1 * GiB, 512 * GiB))
def test_plan_respects_budget_iff_fits(inventory, budget):
    plan = plan_offload(inventory, budget)
    total = sum(t.bytes for t in inventory)
    assert plan.resident_bytes + plan.host_bytes == total
    if plan.fits:
        assert plan.resident_bytes <= budget
    else:
        # everything offloadable was spilled and it still didn't fit
        non_off = sum(t.bytes for t in inventory if not t.offloadable)
        assert plan.resident_bytes >= min(non_off, budget)
    # never offload a non-offloadable tensor
    names_off = set(plan.offloaded) | {n for n, _ in plan.partial}
    for t in inventory:
        if not t.offloadable:
            assert t.name not in names_off
    # partial spills only on divisible tensors, never more than the tensor
    by_name = {t.name: t for t in inventory}
    for n, b in plan.partial:
        assert by_name[n].divisible
        assert 0 < b < by_name[n].bytes


@settings(max_examples=30, deadline=None)
@given(st.lists(tensor_strategy, min_size=1, max_size=10),
       st.integers(1 * GiB, 256 * GiB))
def test_bigger_budget_never_more_traffic(inventory, budget):
    small = plan_offload(inventory, budget)
    large = plan_offload(inventory, budget * 2)
    assert large.host_traffic_per_step <= small.host_traffic_per_step + 1e-6


def test_fine_grained_spills_only_overhang():
    """The paper's headline case: footprint slightly above the slice →
    spill ≈ the overhang, not whole tensors."""
    inv = [TensorInfo("params", 16 * GiB, "param", divisible=True),
           TensorInfo("kv", 500 * GiB, "kv_cache", divisible=True,
                      traffic_multiplier=0.05)]
    budget = 512 * GiB
    plan = plan_offload(inv, budget)
    assert plan.fits
    overhang = 4 * GiB
    assert plan.host_bytes <= overhang + MIN_SPILL_BYTES
    assert plan.resident_bytes <= budget


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(10, 5000))
def test_quantize_roundtrip_error_bounded(seed, n):
    import jax, jax.numpy as jnp
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape, x.size)
    blockwise_max = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(deq - x))) <= blockwise_max / 127.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_error_feedback_is_exact_residual(seed):
    import jax, jax.numpy as jnp
    x = jax.random.normal(jax.random.PRNGKey(seed), (300,), jnp.float32)
    err0 = jnp.zeros_like(x)
    (q, s), err1 = compress_residual(x, err0)
    deq = dequantize_int8(q, s, x.shape, x.size)
    np.testing.assert_allclose(np.asarray(deq + err1), np.asarray(x),
                               rtol=0, atol=1e-5)
