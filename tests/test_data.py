"""Data pipeline: determinism, exact-restart, corpus source, prefetch."""
import os
import tempfile

import numpy as np

from repro.data.pipeline import ByteCorpusSource, DataPipeline, SyntheticSource


def test_synthetic_deterministic_per_step():
    s = SyntheticSource(1000, seed=7)
    a = s.batch(3, 4, 16)
    b = s.batch(3, 4, 16)
    c = s.batch(4, 4, 16)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.dtype == np.int32 and a.shape == (4, 17)
    assert a.min() >= 0 and a.max() < 1000


def test_batch_at_matches_iterator():
    """Restart semantics: batch_at(step) must equal the live stream."""
    s = SyntheticSource(500, seed=1)
    pipe = DataPipeline(s, 2, 8)
    it = iter(pipe)
    streamed = [next(it) for _ in range(3)]
    for step, got in enumerate(streamed):
        want = pipe.batch_at(step)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      want["tokens"])
        np.testing.assert_array_equal(np.asarray(got["labels"]),
                                      want["labels"])


def test_labels_are_shifted_tokens():
    s = SyntheticSource(500, seed=2)
    pipe = DataPipeline(s, 2, 8)
    b = pipe.batch_at(0)
    raw = s.batch(0, 2, 8)
    np.testing.assert_array_equal(b["tokens"], raw[:, :-1])
    np.testing.assert_array_equal(b["labels"], raw[:, 1:])


def test_byte_corpus_source():
    with tempfile.NamedTemporaryFile(delete=False) as f:
        f.write(bytes(range(256)) * 20)
        path = f.name
    try:
        src = ByteCorpusSource(path, seed=0)
        b = src.batch(0, 3, 32)
        assert b.shape == (3, 33)
        assert b.min() >= 0 and b.max() <= 255
        np.testing.assert_array_equal(b, src.batch(0, 3, 32))
    finally:
        os.unlink(path)
