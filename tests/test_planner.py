"""Bounded best-first planning + the incremental probe engine (PR 8).

Three contracts:

1. **SearchPolicy** (cluster/planner.py) — the budgeted best-first
   planner finds the cheapest SLO-preserving action chain: it matches
   the two-step look-ahead on its own showcases (same verdict, no extra
   priced probes) and flips the crafted ``search_showcase`` whose rescue
   chain is *three* evictions deep — beyond ``max_depth=2``.
2. **ProbeCache invalidation** — after ANY randomized apply/rollback
   sequence, every cached probe outcome equals a fresh (uncached) probe
   on every pod: generation counters must invalidate exactly the touched
   pods and nothing less. Property-tested via hypothesis where
   installed, plus a deterministic seeded sweep that runs everywhere.
3. **Cache economics + equivalence** — with the cache on, a replay
   prices >= 3x fewer probe cores on a rescue-heavy trace while every
   scheduling decision (the ``(job_id, place_s, finish_s)`` timeline)
   stays bit-identical to the cache-off run; same for the event-heap
   compaction toggle.
"""
import hashlib

import pytest

from repro.cluster import (ClusterScheduler, PolicySpec, RebalanceController,
                           SearchPolicy, TraceConfig, generate_trace,
                           lookahead_showcase, migration_showcase,
                           search_showcase)
from repro.cluster.actions import (MigrateAcrossPods, Preempt, Shrink,
                                   migrate_victims, preempt_victims,
                                   shrink_victims, slo_profiles)
from repro.cluster.scheduler import JobRecord
from repro.cluster.trace import Job, TRAINING

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # the property still runs via the seeded sweep below
    HAVE_HYPOTHESIS = False


def sha(records):
    return hashlib.sha256(
        repr([(r.job.job_id, r.place_s, r.finish_s)
              for r in records]).encode()).hexdigest()


def _run(trace, n_pods, spec, **kw):
    sched = ClusterScheduler(n_pods=n_pods, policy="frag_repack", spec=spec,
                             **kw)
    records, metrics = sched.run(trace)
    return records, metrics


def _verdict(records, job_id):
    rec = next(r for r in records if r.job.job_id == job_id)
    return bool(rec.finished and rec.finish_s <= rec.deadline_s)


# ---------------------------------------------------------------------------
# 1. SearchPolicy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("selector,hit,preemptions", [
    ("greedy", False, 0),
    ("lookahead", False, 0),
    ("search", True, 3),
])
def test_search_showcase_needs_depth_three(selector, hit, preemptions):
    """Freeing the 16x16 origin takes two enabler evictions plus the
    closing preempt — one action deeper than the look-ahead explores, so
    only the search policy flips the deadline job's verdict."""
    spec = PolicySpec(selector=selector, actions=("shrink", "preempt"))
    records, m = _run(search_showcase(), 1, spec)
    assert _verdict(records, 3) is hit
    assert m.preemptions == preemptions
    if selector == "search":
        assert m.resumes == 3   # every evicted batch job resumes


def test_search_matches_lookahead_on_its_showcases():
    """On the two-step showcases the search policy commits the same
    rescue chains as the look-ahead — same SLO verdicts, same action
    counts — without pricing extra probes (the bound cuts the rest)."""
    for trace_fn, n_pods, acts, jid in (
            (lookahead_showcase, 1, ("shrink", "preempt"), 3),
            (migration_showcase, 2, ("shrink", "preempt", "migrate"), 3)):
        base = {}
        for selector in ("lookahead", "search"):
            records, m = _run(trace_fn(), n_pods,
                              PolicySpec(selector=selector, actions=acts))
            base[selector] = (m.preemptions, m.migrations, m.shrinks,
                              m.rescue_probes_priced + m.probe_cache_hits)
            assert _verdict(records, jid), (trace_fn.__name__, selector)
        la, se = base["lookahead"], base["search"]
        assert se[:3] == la[:3], trace_fn.__name__
        # bounded probe count: at most the configured budget on top of
        # what the look-ahead's own scan probes
        assert se[3] <= la[3] + SearchPolicy().budget_probes


def test_search_depth_two_is_lookahead_bounded():
    """``max_depth=2`` restricts the search to one enabler + closer — the
    look-ahead's regime — so the three-eviction showcase stays a miss,
    and a zero probe budget degenerates to the greedy root scan."""
    for policy in (SearchPolicy(max_depth=2), SearchPolicy(budget_probes=0)):
        spec = PolicySpec(selector="search", actions=("shrink", "preempt"))
        sched = ClusterScheduler(n_pods=1, policy="frag_repack", spec=spec)
        sched.selector = policy   # rebind the constructed selector
        records, m = sched.run(search_showcase())
        assert not _verdict(records, 3)
        assert m.preemptions == 0


def test_rebalance_controller_flips_power_blocked_miss():
    """With cross-pod migration off-policy, the deadline job on the
    migration showcase is power-blocked and misses; the proactive
    rebalancer notices the headroom spread at a CONTROL tick, probes a
    MigrateTenant off the chip-packed cool pod, and the job then places
    directly — no reactive rescue involved."""
    spec = PolicySpec(actions=("shrink", "preempt"))
    records, m = _run(migration_showcase(), 2, spec, horizon_s=3000.0)
    assert not _verdict(records, 3)

    ctrl = RebalanceController(interval_s=5.0, spread_watts=100.0)
    records, m = _run(migration_showcase(), 2, spec, autoscaler=ctrl,
                      horizon_s=3000.0)
    assert _verdict(records, 3)
    assert ctrl.moves == 1 and ctrl.probes >= 1
    assert m.autoscale_resizes == 1   # surfaces in the metrics column
    assert m.migrations == 1          # the proactive move, priced as DCN
    assert m.preemptions == 0 and m.shrinks == 0   # no reactive rescue


# ---------------------------------------------------------------------------
# 2. ProbeCache invalidation (the ISSUE satellite property)
# ---------------------------------------------------------------------------
_PROFILES = ("1s.16c", "2s.32c", "4s.64c", "8s.128c")
_KINDS = ("shrink", "preempt", "migrate")


def _mid_state(seed, n_pods=2, horizon=400.0):
    trace = generate_trace(TraceConfig(seed=seed, n_jobs=14,
                                       mean_interarrival_s=20.0))
    sched = ClusterScheduler(n_pods=n_pods, policy="frag_repack",
                             horizon_s=horizon, spec=PolicySpec())
    sched.run(trace)
    return sched


def _beneficiary(sched, i, profile):
    t = sched._now
    job = Job(job_id=10_000 + i, kind=TRAINING, arch="llama3-8b",
              shape="train_4k", arrival_s=t, steps=5, profile=profile,
              slo_factor=50.0, priority=3)
    from repro.cluster.placement import ideal_duration
    ideal = ideal_duration(job, sched.chip, sched.perf)
    return JobRecord(job, deadline_s=(t + 50.0 * ideal
                                      if ideal is not None else None))


def _enumerate_rescues(sched, rec, t):
    """Every bindable rescue action on the current state, scan order —
    the exhaustive version of what the finders walk first-feasible."""
    acts = []
    scs = list(slo_profiles(sched, rec, t))
    for sc in scs:
        for pod in sched.pods:
            for victim in shrink_victims(pod, rec):
                for small in sched.perf.options(victim.job,
                                                ignore_pin=True):
                    if small.profile.n_chips >= victim.n_chips:
                        continue
                    acts.append(Shrink(rec, pod, victim, small, sc))
            for victim in preempt_victims(pod, rec):
                acts.append(Preempt(rec, pod, victim, sc))
        for src in sched.pods:
            for victim in migrate_victims(src, rec):
                for dest in sched.pods:
                    if dest is not src:
                        acts.append(MigrateAcrossPods(rec, src, victim,
                                                      dest, sc))
    return acts


def _outcomes(sched, rec, t):
    out = []
    for act in _enumerate_rescues(sched, rec, t):
        o = act.probe(sched, t)
        out.append((type(act).__name__, act.victim_id, o.feasible,
                    o.cost_s, o.start_delay_s, o.projected_finish_s,
                    o.meets_slo, o.reason))
    return out


def _cache_consistency_body(seed, kinds, profiles):
    """Warm the cache, mutate the cluster through a randomized
    apply/rollback sequence, then require every cached probe outcome to
    equal a fresh uncached probe on every pod."""
    from repro.cluster.actions import Preempt as P, Shrink as S, \
        MigrateAcrossPods as M
    finders = {"shrink": S.find, "preempt": P.find, "migrate": M.find}
    sched = _mid_state(seed)
    t = sched._now
    applied = []
    for i, kind in enumerate(kinds):
        rec = _beneficiary(sched, i, profiles[i % len(profiles)])
        _outcomes(sched, rec, t)          # fill / hit cache entries
        act = finders[kind](sched, rec, t)
        if act is not None:
            act.apply(sched, t)
            applied.append(act)
        if applied and i % 2:
            applied.pop().rollback(sched)  # interleave rollbacks
    while applied:
        applied.pop().rollback(sched)
    rec = _beneficiary(sched, 99, profiles[0])
    cached = _outcomes(sched, rec, t)
    keep, sched.probe_cache = sched.probe_cache, None
    fresh = _outcomes(sched, rec, t)
    sched.probe_cache = keep
    assert cached == fresh
    return sched._probe_hits


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 7),
           kinds=st.lists(st.sampled_from(_KINDS), min_size=1, max_size=4),
           profiles=st.lists(st.sampled_from(_PROFILES), min_size=4,
                             max_size=4))
    def test_cached_probes_match_fresh_after_random_mutation(seed, kinds,
                                                             profiles):
        _cache_consistency_body(seed, kinds, profiles)


def test_cached_probes_match_fresh_seeded_sweep():
    """Hypothesis-free sweep of the same property; the accumulated hit
    count proves the sweep actually exercised cache reuse, not just
    misses."""
    import random
    rng = random.Random(2)
    hits = 0
    for seed in range(4):
        kinds = [rng.choice(_KINDS) for _ in range(4)]
        profiles = [rng.choice(_PROFILES) for _ in range(4)]
        hits += _cache_consistency_body(seed, kinds, profiles)
    for kind in _KINDS:
        hits += _cache_consistency_body(1, [kind] * 2, list(_PROFILES))
    assert hits > 0


# ---------------------------------------------------------------------------
# 3. cache economics + toggle equivalence
# ---------------------------------------------------------------------------
def test_probe_cache_cuts_priced_probes_3x_with_identical_decisions():
    """On a rescue-heavy seeded trace the cache serves the bulk of probe
    cores from memoized entries (>= 3x fewer priced) while the timeline
    stays bit-identical to the cache-off replay — the tentpole economy
    claim, at test scale (the 10k-job version is gated in check_perf)."""
    trace = generate_trace(TraceConfig(seed=0, n_jobs=1200,
                                       mean_interarrival_s=12.0))
    spec = PolicySpec(selector="lookahead",
                      actions=("shrink", "preempt", "migrate"))
    shas, metrics = {}, {}
    for cache in (True, False):
        records, m = _run(trace, 4, spec, probe_cache=cache)
        shas[cache], metrics[cache] = sha(records), m
    assert shas[True] == shas[False]
    on, off = metrics[True], metrics[False]
    assert on.makespan_s == off.makespan_s
    assert off.probe_cache_hits == 0
    assert on.rescue_probes_priced + on.probe_cache_hits \
        == off.rescue_probes_priced
    assert on.rescue_probes_priced * 3 <= off.rescue_probes_priced
    assert on.probe_cache_hits > 0


def test_heap_compaction_toggle_is_bit_identical():
    """The tick-heap compaction (default on) must group integration
    ticks exactly as the uncompacted heap does — same timeline sha on a
    queue-heavy trace either way."""
    trace = generate_trace(TraceConfig(seed=0, n_jobs=48,
                                       mean_interarrival_s=5.0))
    shas = {}
    for compaction in (True, False):
        records, _ = _run(trace, 1, PolicySpec(),
                          heap_compaction=compaction)
        shas[compaction] = sha(records)
    assert shas[True] == shas[False]
