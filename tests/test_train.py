"""Training substrate: loss descent, grad-accumulation exactness, checkpoint
roundtrip + corruption resistance, fault-tolerant restart path."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import ShapeSuite, TRAIN
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.models.common import host_axis_env
from repro.models.model_zoo import build_model
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.train_step import _accumulate_grads

ENV = host_axis_env()


def _tiny_model(arch="gpt2-124m", **kw):
    cfg = get_config(arch).reduced().with_(**kw)
    model = build_model(cfg, ENV)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_loss_decreases():
    cfg, model, params = _tiny_model()
    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100)
    state = adamw.init(params)
    src = SyntheticSource(cfg.vocab_size, seed=3)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        p, s, _ = adamw.update(opt_cfg, grads, state, params)
        return p, s, loss

    losses = []
    for i in range(25):
        arr = src.batch(i, 4, 32)
        batch = {"tokens": jnp.asarray(arr[:, :-1]),
                 "labels": jnp.asarray(arr[:, 1:])}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_grads_match_full_batch():
    # fp32 activations so the only difference is summation order
    cfg, model, params = _tiny_model(remat="none", dtype="float32")
    batch = model.synthetic_batch(ShapeSuite("t", TRAIN, 32, 4))
    loss1, g1 = _accumulate_grads(model, params, batch, 1)
    loss4, g4 = _accumulate_grads(model, params, batch, 4)
    # microbatch mean-of-means == full mean (equal microbatch sizes)
    np.testing.assert_allclose(float(loss1), float(loss4), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_checkpoint_roundtrip_and_gc():
    _, model, params = _tiny_model()
    tree = {"params": params, "step": jnp.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.latest_step(d) == 40
        kept = sorted(os.listdir(d))
        assert len([k for k in kept if k.startswith("step_")]) == 2
        restored, s = ckpt.restore(d, tree)
        assert s == 40
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_volume_matches_files_written():
    # volume_bytes is the quantity PerfModel.checkpoint_cost prices for a
    # preemption: it must equal the payload save() actually writes
    tree = {"a": jnp.ones((8, 4), jnp.float32), "b": jnp.zeros(3, jnp.int32)}
    vol = ckpt.volume_bytes(tree)
    assert vol == 8 * 4 * 4 + 3 * 4
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        step_dir = os.path.join(d, "step_00000001")
        on_disk = sum(np.load(os.path.join(step_dir, f)).nbytes
                      for f in os.listdir(step_dir) if f.endswith(".npy"))
        assert on_disk == vol


def test_checkpoint_rejects_wrong_structure():
    _, model, params = _tiny_model()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"params": params})
        with pytest.raises(ValueError):
            ckpt.restore(d, {"params": params, "extra": jnp.zeros(3)})


def test_fault_runner_restarts_and_repartitions():
    from repro.core.partitioner import StaticPartitioner
    from repro.core.slices import get_profile
    from repro.train.fault import (FaultTolerantRunner, RunnerConfig,
                                   StepFailure)
    cfg, model, _ = _tiny_model()
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=60)
    src = SyntheticSource(cfg.vocab_size, seed=5)
    pipe = DataPipeline(src, 2, 16)

    def build_step(profile):
        params, _ = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw.init(params)}
        latest = ckpt.latest_step(d)
        if latest is not None:
            state, _ = ckpt.restore(d, state)

        @jax.jit
        def jstep(state, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(state["params"],
                                                            batch)
            p, o, met = adamw.update(opt_cfg, grads, state["opt"],
                                     state["params"])
            met["loss"] = loss
            return {"params": p, "opt": o}, met

        def step(state, batch):
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            state, met = jstep(state, b)
            return state, {k: float(v) for k, v in met.items()}
        return step, state

    part = StaticPartitioner()
    prof = get_profile("8s.128c")
    part.allocate(prof)
    fired = []

    def fail_hook(step):
        if step == 12 and not fired:
            fired.append(step)
            part.fail_chips([(0, 0)])
            raise StepFailure("injected")

    with tempfile.TemporaryDirectory() as d:
        runner = FaultTolerantRunner(
            RunnerConfig(ckpt_dir=d, ckpt_every=5, max_restarts=2),
            part, prof, build_step, pipe.batch_at, lambda s: s, fail_hook)
        stats = runner.run(20)
    assert stats.restarts == 1
    assert stats.repartitions  # moved to a smaller/other slice
    assert stats.steps_done >= 20
