"""Docs stay true: every relative markdown link under docs/ resolves to a
real file, and the code blocks in docs/scheduling.md execute as doctests
(the worked example cannot rot). CI runs this file as the docs job."""
import doctest
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

# [text](target) — inline markdown links
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)


def _markdown_files():
    return sorted(DOCS.glob("*.md"))


def test_docs_directory_has_the_site():
    names = {p.name for p in _markdown_files()}
    assert {"index.md", "scheduling.md", "cluster.md", "perfmodel.md",
            "serving.md", "autoscaling.md", "offloading.md",
            "hardware.md"} <= names


@pytest.mark.parametrize("md", _markdown_files(), ids=lambda p: p.name)
def test_relative_links_resolve(md):
    text = md.read_text(encoding="utf-8")
    # don't treat links inside fenced code blocks as navigation
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    broken = []
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            broken.append(target)
    assert not broken, f"{md.name}: broken relative link(s) {broken}"


@pytest.mark.parametrize("name", ["scheduling.md", "cluster.md",
                                  "autoscaling.md", "offloading.md",
                                  "hardware.md"])
def test_worked_examples_execute(name, monkeypatch):
    monkeypatch.chdir(REPO)   # examples use repo-relative fixture paths
    text = (DOCS / name).read_text(encoding="utf-8")
    blocks = [b for b in _CODE_BLOCK_RE.findall(text) if ">>>" in b]
    assert blocks, f"{name} must carry runnable >>> examples"
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(
        optionflags=doctest.NORMALIZE_WHITESPACE | doctest.ELLIPSIS)
    globs = {}   # blocks share state, like one top-to-bottom session
    for i, block in enumerate(blocks):
        test = parser.get_doctest(block, globs, f"{name}[{i}]",
                                  f"docs/{name}", 0)
        runner.run(test, clear_globs=False)
        globs = test.globs
    assert runner.failures == 0, (
        f"{runner.failures} doctest failure(s) in docs/{name}")
