"""Public-trace CSV loader: Philly/Alibaba-style schemas onto ``Job``s."""
import os

import pytest

from repro.cluster import ClusterScheduler, Job, load_csv
from repro.cluster.trace import BATCH, SERVING, TRAINING, KIND_PRIORITY

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "philly_mini.csv")


def test_fixture_loads_and_maps():
    jobs = load_csv(FIXTURE)
    assert len(jobs) == 10
    assert all(isinstance(j, Job) for j in jobs)
    # rows arrive sorted by submit time; job ids follow that order
    assert [j.arrival_s for j in jobs] == sorted(j.arrival_s for j in jobs)
    assert [j.job_id for j in jobs] == list(range(10))
    # public-trace class vocabulary → the three paper classes
    assert [j.kind for j in jobs] == [
        TRAINING, BATCH, TRAINING, SERVING, BATCH,
        TRAINING, SERVING, BATCH, TRAINING, BATCH]
    # GPU request → smallest fitting profile (an oversized request raises
    # rather than clamping — see test_oversized_gpu_request_raises)
    assert [j.profile for j in jobs] == [
        "1s.16c", "1s.16c", "4s.64c", "1s.16c", "1s.16c",
        "8s.128c", "1s.16c", "2s.32c", "16s.256c", "16s.256c"]
    # observed runtimes are pinned wall-clock durations
    assert [j.duration_s for j in jobs] == [
        600.0, 120.0, 900.0, 45.0, 300.0, 1200.0, 60.0, 240.0, 500.0, 90.0]
    for j in jobs:
        assert j.priority == KIND_PRIORITY[j.kind]
        assert j.requests == (2 if j.kind == SERVING else 0)


def test_alibaba_style_aliases(tmp_path):
    p = tmp_path / "alibaba.csv"
    p.write_text("timestamp,runtime,plan_gpu,type\n"
                 "5.5,100,17,inference\n"
                 "1.25,50,2,train\n")
    jobs = load_csv(str(p))
    # sorted by submit time, not file order
    assert [j.arrival_s for j in jobs] == [1.25, 5.5]
    assert [j.kind for j in jobs] == [TRAINING, SERVING]
    assert jobs[1].profile == "2s.32c"   # 17 chips → next profile up


def test_missing_class_column_uses_default(tmp_path):
    p = tmp_path / "noclass.csv"
    p.write_text("arrival_s,duration_s,gpus\n0,10,1\n1,10,1\n")
    assert all(j.kind == BATCH for j in load_csv(str(p)))
    assert all(j.kind == TRAINING
               for j in load_csv(str(p), default_kind=TRAINING))


def test_optional_overrides(tmp_path):
    p = tmp_path / "rich.csv"
    p.write_text(
        "arrival_s,duration_s,gpus,kind,job_id,arch,slo_factor,u_compute\n"
        "0,10,16,batch,7,gpt2-124m,2.5,0.2\n")
    (j,) = load_csv(str(p))
    assert (j.job_id, j.arch, j.slo_factor, j.u_compute) == \
        (7, "gpt2-124m", 2.5, 0.2)


@pytest.mark.parametrize("body,err", [
    ("duration_s,gpus\n10,1\n", "submit-time"),
    ("arrival_s,gpus\n0,1\n", "duration"),
    ("arrival_s,duration_s\n0,10\n", "GPU-request"),
    ("arrival_s,duration_s,gpus\n0,0,1\n", "non-positive duration"),
    ("arrival_s,duration_s,gpus\n0,10,0\n", "non-positive GPU"),
    ("arrival_s,duration_s,gpus,kind\n0,10,1,weird\n", "unknown job class"),
    ("arrival_s,duration_s,gpus\n0,10,257\n", "exceeds the largest"),
    ("arrival_s,duration_s,gpus,job_id\n0,10,1,3\n1,10,1,3\n",
     "duplicate job_id"),
    ("", "empty"),
])
def test_rejects_malformed(tmp_path, body, err):
    p = tmp_path / "bad.csv"
    p.write_text(body)
    with pytest.raises(ValueError, match=err):
        load_csv(str(p))


def test_oversized_gpu_request_raises(tmp_path):
    # a request beyond the largest profile must raise, not clamp: a
    # clamped job would replay on a quarter of the chips the trace says
    # it used, silently skewing every downstream throughput number
    p = tmp_path / "big.csv"
    p.write_text("arrival_s,duration_s,gpus\n0,10,300\n")
    with pytest.raises(ValueError, match="300 exceeds the largest"):
        load_csv(str(p))
    # the boundary itself is fine: 256 chips is exactly the full pod
    p.write_text("arrival_s,duration_s,gpus\n0,10,256\n")
    (j,) = load_csv(str(p))
    assert j.profile == "16s.256c"


def test_duplicate_job_ids_raise(tmp_path):
    # the scheduler keys records by job_id — a duplicate would silently
    # merge two jobs into one record. The error names both rows.
    p = tmp_path / "dup.csv"
    p.write_text("arrival_s,duration_s,gpus,job_id\n"
                 "0,10,1,7\n5,10,1,8\n9,10,1,7\n")
    with pytest.raises(ValueError, match=r"duplicate job_id 7"):
        load_csv(str(p))
    # explicit ids that don't collide load fine
    p.write_text("arrival_s,duration_s,gpus,job_id\n0,10,1,7\n5,10,1,8\n")
    assert [j.job_id for j in load_csv(str(p))] == [7, 8]


@pytest.mark.parametrize("chip", ["v5e", "mi300"])
def test_arch_fit_goes_through_chip_registry(chip):
    # regression: the arch-fit used to hard-wire the v5e roofline; it now
    # maps through the chip registry, so every family loads and the fit is
    # computed against *that* chip's constants
    jobs = load_csv(FIXTURE, chip=chip)
    assert len(jobs) == 10
    assert all(j.arch for j in jobs)
    # structural columns (profile, kind, duration) are chip-independent
    base = load_csv(FIXTURE)
    assert [j.profile for j in jobs] == [j.profile for j in base]
    assert [j.kind for j in jobs] == [j.kind for j in base]


def test_chip_registry_fit_is_chip_sensitive():
    # the mi300 roofline (different flops:bw ratio) picks a different arch
    # for at least one row — proof the fit reads the selected chip, not a
    # baked-in v5e model
    v5e = [j.arch for j in load_csv(FIXTURE)]
    mi300 = [j.arch for j in load_csv(FIXTURE, chip="mi300")]
    assert v5e != mi300


def test_unknown_chip_fails_readably():
    with pytest.raises(ValueError, match=r"unknown chip 'h100'.*mi300.*v5e"):
        load_csv(FIXTURE, chip="h100")


def test_unknown_arch_override_fails_readably(tmp_path):
    # a pinned arch outside the model registry used to leak a raw
    # KeyError from repro.configs deep inside the fit scan; it now fails
    # at the offending row with the known-arch vocabulary
    p = tmp_path / "badarch.csv"
    p.write_text("arrival_s,duration_s,gpus,arch\n"
                 "0,10,1,llama3-8b\n"
                 "1,10,1,falcon-999b\n")
    with pytest.raises(ValueError,
                       match=r":3: unknown arch 'falcon-999b'.*llama3-8b"):
        load_csv(str(p))


def test_fixture_replays_deterministically():
    jobs = load_csv(FIXTURE)
    runs = []
    for _ in range(2):
        sched = ClusterScheduler(n_pods=1, policy="frag_repack")
        records, metrics = sched.run(list(jobs))
        runs.append([(r.job.job_id, r.place_s, r.finish_s) for r in records])
        assert metrics.completed == len(jobs)   # pinned durations, no horizon
    assert runs[0] == runs[1]
