"""Twin-offload co-execution (PR 9): CPU-side throughput priced as
elastic rungs, plus the property-test hardening pass over the
offload/pricing core.

Layers covered:

* ``plan_offload`` / ``plan_twin`` invariants — budget respect,
  indivisible tensors never split, spill monotone in budget, shard
  fractions in (0, 1], the two-resource step time is the max of its
  terms — via hypothesis when available and a seeded sweep otherwise
  (the ``test_actions.py`` convention).
* ``estimated_step_slowdown``'s replacement: the old model assumed the
  host link overlaps perfectly with compute (``max(base, t_host)``);
  the new one charges a non-overlappable serial prefix, which bites
  hardest in the crossover region where the terms are comparable.
* The twin rungs end-to-end: ``options`` ordering, the default-off
  bit-identity contract, the probe-cache key discipline, the
  ``twin_showcase`` SLO flip, and the serving runtime's report block.
"""
import pytest

from repro.cluster import ClusterScheduler, PolicySpec, TraceConfig, \
    generate_trace, twin_showcase
from repro.cluster.trace import SERVING, Job
from repro.configs import get_config, get_shape
from repro.core.hw import V5E, V5E_HOST, V5E_HOST_C2C, GiB, HostSpec
from repro.core.offload import (OVERLAP_SERIAL_FRACTION, TensorInfo,
                                TwinOffloadPlan, TwinSpec,
                                estimated_step_slowdown, overlap_step_time,
                                plan_offload, plan_twin)
from repro.core.perfmodel import PerfModel, get_model
from repro.core.slices import PROFILES, get_profile
from repro.core.workload import WorkloadEstimate

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # the properties still run via the seeded sweeps below
    HAVE_HYPOTHESIS = False

TWIN = TwinSpec()


# ---------------------------------------------------------------------------
# property bodies (shared by the hypothesis wrappers and the seeded sweeps)
# ---------------------------------------------------------------------------
def _inventory(sizes, divisibility):
    return [TensorInfo(name=f"t{i}", bytes=b, group="param", divisible=d)
            for i, (b, d) in enumerate(zip(sizes, divisibility))]


def _offload_invariants_body(sizes, divisibility, budget_frac):
    """plan_offload respects both budgets, never splits an indivisible
    tensor, and spills monotonically less as the budget grows."""
    inv = _inventory(sizes, divisibility)
    total = sum(t.bytes for t in inv)
    budget = int(total * budget_frac)
    host_budget = total * 2
    plan = plan_offload(inv, budget, host_budget=host_budget)
    if plan.fits:
        assert plan.resident_bytes <= budget
        assert plan.host_bytes <= host_budget
    # indivisible tensors are moved whole or not at all
    partial_names = {n for n, _ in plan.partial}
    for t in inv:
        if not t.divisible:
            assert t.name not in partial_names
    # monotone: a strictly larger budget never spills more
    bigger = plan_offload(inv, budget + max(1, total // 7),
                          host_budget=host_budget)
    assert bigger.host_bytes <= plan.host_bytes
    return 1


_TWIN_CASES = [
    ("llama3-8b", "decode_32k", "1s.16c"),
    ("llama3-8b", "decode_32k", "2s.32c"),
    ("qwen3-32b", "decode_32k", "2s.32c"),
    ("qwen3-32b", "train_4k", "4s.64c"),
    ("command-r-35b", "decode_32k", "2s.32c"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k", "2s.32c"),
    ("qwen2-vl-72b", "decode_32k", "4s.64c"),
    ("gpt2-124m", "decode_32k", "1s.16c"),
]


def _twin_invariants_body(arch, shape_name, profile_name, host):
    """plan_twin shard fractions live in (0, 1], the plan's step time is
    exactly the max of its three resource terms, and the overlap-model
    slowdown never undercuts the ideal-overlap bound."""
    wl = WorkloadEstimate(get_config(arch), get_shape(shape_name))
    profile = get_profile(profile_name)
    tp = wl.twin_plan_for(profile, host=host)
    if tp is None:
        return 0
    assert tp.shards, "a twin plan with no shards should be None"
    for shard in tp.shards:
        assert 0.0 < shard.cpu_fraction <= 1.0
        assert shard.flops >= 0 and shard.cpu_bytes >= 0
    assert 0.0 <= tp.cpu_fraction <= 1.0
    assert tp.t_cpu >= 0.0 and tp.t_link >= 0.0
    assert tp.step_time == max(tp.gpu_floor_s, tp.t_cpu, tp.t_link)
    for base in (tp.gpu_floor_s * 0.5, tp.gpu_floor_s, tp.gpu_floor_s * 4):
        slow = estimated_step_slowdown(tp, base, profile)
        assert slow >= max(base, tp.gpu_floor_s, tp.t_cpu, tp.t_link)
    return 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(sizes=st.lists(st.integers(1 * GiB // 8, 64 * GiB),
                          min_size=1, max_size=8),
           div=st.data(),
           budget_frac=st.floats(0.05, 1.2))
    def test_offload_invariants(sizes, div, budget_frac):
        divisibility = [div.draw(st.booleans()) for _ in sizes]
        _offload_invariants_body(sizes, divisibility, budget_frac)

    @settings(max_examples=20, deadline=None)
    @given(case=st.sampled_from(_TWIN_CASES),
           host=st.sampled_from([V5E_HOST, V5E_HOST_C2C,
                                 HostSpec(name="fat", cpu_flops=12e12,
                                          dram_bw=800e9)]))
    def test_twin_invariants(case, host):
        _twin_invariants_body(*case, host)


def test_offload_invariants_seeded_sweep():
    import random
    rng = random.Random(0)
    total = 0
    for _ in range(20):
        n = rng.randint(1, 8)
        sizes = [rng.randint(1 * GiB // 8, 64 * GiB) for _ in range(n)]
        divisibility = [rng.random() < 0.5 for _ in range(n)]
        total += _offload_invariants_body(sizes, divisibility,
                                          rng.uniform(0.05, 1.2))
    assert total >= 5


def test_twin_invariants_seeded_sweep():
    """Hypothesis-free sweep of the same property; at least a handful of
    cases must actually produce a twin plan (the sweep is not vacuous)."""
    total = 0
    for case in _TWIN_CASES:
        for host in (V5E_HOST, V5E_HOST_C2C):
            total += _twin_invariants_body(*case, host)
    assert total >= 5


def test_coherent_link_never_slows_the_twin():
    # the C2C-coherent host scales the effective link up 8x, so the best
    # twin step time can only improve (or the plan disappears because the
    # plain path no longer needs help)
    wl = WorkloadEstimate(get_config("llama3-8b"), get_shape("decode_32k"))
    profile = get_profile("1s.16c")
    base = wl.twin_plan_for(profile, host=V5E_HOST)
    c2c = wl.twin_plan_for(profile, host=V5E_HOST_C2C)
    assert base is not None
    if c2c is not None:
        assert c2c.step_time <= base.step_time
    assert V5E_HOST.effective_link_scale() == 1.0
    assert V5E_HOST_C2C.effective_link_scale() == V5E_HOST_C2C.c2c_scale


# ---------------------------------------------------------------------------
# estimated_step_slowdown: the full-overlap assumption is gone
# ---------------------------------------------------------------------------
def test_overlap_step_time_crossover_region():
    # the old model returned max(base, t_host): perfect overlap, so two
    # equal terms cost the same as one. The replacement charges a serial
    # prefix of the second-largest term, which is exactly where the old
    # model was most wrong.
    assert overlap_step_time(1.0, 0.0, 0.0) == 1.0     # nothing to overlap
    for t in (0.1, 0.5, 1.0, 2.0, 10.0):
        v = overlap_step_time(1.0, 0.0, t)
        ideal = max(1.0, t)
        assert v >= ideal                              # never below the bound
        assert v == ideal + OVERLAP_SERIAL_FRACTION * min(1.0, t)
    # the overhead RATIO over ideal overlap peaks at the crossover
    ratio = {t: overlap_step_time(1.0, 0.0, t) / max(1.0, t)
             for t in (0.1, 1.0, 10.0)}
    assert ratio[1.0] == 1.0 + OVERLAP_SERIAL_FRACTION
    assert ratio[1.0] > ratio[0.1] and ratio[1.0] > ratio[10.0]
    # three-term form: only the second-largest pays the serial prefix
    assert overlap_step_time(1.0, 0.8, 0.3) == 1.0 + 0.1 * 0.8


def test_step_slowdown_charges_serial_prefix_on_real_plan():
    # a plan with real host traffic: the old max() model would price the
    # crossover point at exactly base_step_time; the replacement must
    # price it strictly higher, and converge to ~base under dominance
    wl = WorkloadEstimate(get_config("llama3-8b"), get_shape("decode_32k"))
    profile = get_profile("1s.16c")
    plan = wl.plan_for(profile)
    assert plan.fits and plan.host_traffic_per_step > 0
    t_link = plan.host_traffic_per_step / profile.host_link_bw(V5E)
    crossover = estimated_step_slowdown(plan, t_link, profile)
    assert crossover == pytest.approx(t_link * (1 + OVERLAP_SERIAL_FRACTION))
    assert crossover > max(t_link, t_link)             # old model's answer
    dominated = estimated_step_slowdown(plan, 100.0 * t_link, profile)
    assert dominated == pytest.approx(100.0 * t_link, rel=0.01)
    # a coherent host scales the link term down
    c2c = estimated_step_slowdown(plan, t_link, profile, host=V5E_HOST_C2C)
    assert c2c < crossover


# ---------------------------------------------------------------------------
# the rungs: options ordering, default-off bit-identity, memoization
# ---------------------------------------------------------------------------
def _job(arch="llama3-8b", shape="decode_32k", profile=None, steps=10):
    return Job(job_id=0, kind=SERVING, arch=arch, shape=shape,
               arrival_s=0.0, steps=steps, profile=profile)


def test_options_emit_twin_rungs_plain_first():
    on = PerfModel(V5E, twin=TWIN)
    rungs = [sc.rung for sc in on.options(_job())]
    assert any("+cpu" in r for r in rungs), rungs
    for sc in on.options(_job()):
        if sc.twin is None:
            continue
        assert sc.rung == f"{sc.profile.name}+cpu{sc.twin.cpu_fraction:.2f}"
        plain = next(s for s in on.options(_job())
                     if s.profile.name == sc.profile.name and s.twin is None)
        # the twin rung is strictly better perf-per-chip at equal chips...
        assert plain.step_time / sc.step_time >= TWIN.min_speedup
        # ...and sorts right after its plain sibling
        assert rungs.index(plain.rung) + 1 == rungs.index(sc.rung)
    # chips stay non-decreasing across the whole row
    chips = [sc.profile.n_chips for sc in on.options(_job())]
    assert chips == sorted(chips)


def test_twin_disabled_is_bit_identical():
    off = PerfModel(V5E)
    on = PerfModel(V5E, twin=TWIN)
    job = _job()
    plain_on = [sc for sc in on.options(job) if sc.twin is None]
    assert [sc.rung for sc in off.options(job)] == \
        [sc.rung for sc in plain_on]
    for a, b in zip(off.options(job), plain_on):
        assert a.step_time == b.step_time          # bit-identical floats
        assert a.terms == b.terms
        assert a.perf_per_chip == b.perf_per_chip
    # the twin-off profile_key carries no twin token; twin-on does
    assert not any("twin" in str(part) for part in off.profile_key)
    assert on.profile_key[:len(off.profile_key)] == off.profile_key
    assert "twin" in str(on.profile_key[-1])


def test_get_model_memoizes_per_twin_spec():
    assert get_model() is get_model()
    assert get_model(twin=TWIN) is get_model(twin=TwinSpec())
    assert get_model(twin=TWIN) is not get_model()
    assert get_model().twin is None
    assert get_model(twin=TWIN).twin == TWIN


def test_scheduler_twin_kwarg_forms():
    assert ClusterScheduler(n_pods=1).perf.twin is None
    assert ClusterScheduler(n_pods=1, twin=True).perf.twin == TwinSpec()
    custom = TwinSpec(host=V5E_HOST_C2C)
    assert ClusterScheduler(n_pods=1, twin=custom).perf.twin == custom


# ---------------------------------------------------------------------------
# the showcase: one flag, opposite SLO verdicts
# ---------------------------------------------------------------------------
def _run_twin_showcase(twin, **kw):
    sched = ClusterScheduler(n_pods=1, policy="frag_repack",
                             spec=PolicySpec(actions=("shrink", "preempt")),
                             twin=twin, **kw)
    records, metrics = sched.run(twin_showcase())
    deadline_job = next(r for r in records if r.job.job_id == 4)
    victim = next(r for r in records if r.job.job_id == 2)
    return records, metrics, deadline_job, victim


def test_twin_showcase_off_misses_slo():
    _, metrics, dj, victim = _run_twin_showcase(False)
    # no plain rung both meets the deadline and fits the 4x4 a shrink can
    # mint; preemption finds no lower-priority victim — the job queues
    # behind the holders and misses
    assert metrics.shrinks == 0 and metrics.preemptions == 0
    assert dj.place_s > dj.deadline_s
    assert dj.finish_s > dj.deadline_s
    assert "+cpu" not in dj.rung
    assert victim.profile_name == "2s.32c" and not victim.shrunk


def test_twin_showcase_on_rescues_via_twin_rung():
    _, metrics, dj, victim = _run_twin_showcase(True)
    assert metrics.shrinks == 1 and metrics.preemptions == 0
    assert victim.shrunk and victim.profile_name == "1s.16c"
    assert dj.place_s == pytest.approx(10.0)
    assert dj.finished and dj.finish_s <= dj.deadline_s
    # the committed rung is the twin: same rectangle, CPU co-execution
    assert dj.rung.startswith("1s.16c+cpu")
    assert dj.profile_name == "1s.16c"   # grid bookkeeping keeps base names


def test_twin_showcase_deadline_identical_both_modes():
    # the deadline derives from the big clean profiles (no twin rungs
    # there), so enabling twin pricing must not move the goalposts
    _, _, dj_off, _ = _run_twin_showcase(False)
    _, _, dj_on, _ = _run_twin_showcase(True)
    assert dj_off.deadline_s == dj_on.deadline_s


def test_twin_probe_cache_never_collides_rungs():
    # Shrink/Preempt/Migrate cache keys use PerfScore.rung, so a twin and
    # a plain score on the same rectangle stay distinct entries: cached
    # and uncached replays must commit identical timelines
    a = _run_twin_showcase(True, probe_cache=True)
    b = _run_twin_showcase(True, probe_cache=False)
    ta = [(r.job.job_id, r.place_s, r.finish_s) for r in a[0]]
    tb = [(r.job.job_id, r.place_s, r.finish_s) for r in b[0]]
    assert ta == tb
    assert a[2].rung == b[2].rung


# ---------------------------------------------------------------------------
# the default-off pin contract, in the same session as the twin modules
# ---------------------------------------------------------------------------
def test_trace0_pins_bit_identical_with_twin_models_loaded():
    """Replaying the PR 2/3 golden AFTER twin-enabled models have been
    built and scored must still match the frozen sha: the twin machinery
    lives in separate memo tables and never leaks into default pricing."""
    from test_timeline_pins import TRACE0_PINS, sha
    on = get_model(twin=TWIN)
    on.options(_job())                      # populate twin memo tables
    jobs = generate_trace(TraceConfig(seed=0, n_jobs=48,
                                      mean_interarrival_s=5.0))
    for frozen, (expected_sha, expected_makespan) in TRACE0_PINS.items():
        sched = ClusterScheduler(n_pods=1, frozen_durations=frozen)
        records, metrics = sched.run(jobs)
        assert sha(records) == expected_sha
        assert metrics.makespan_s == expected_makespan


def test_showcase_pins_bit_identical_with_twin_models_loaded():
    from test_timeline_pins import SHOWCASE_PINS, sha
    get_model(twin=TWIN).options(_job())    # twin tables live and warm
    for name, (trace_fn, kwargs, expected) in sorted(SHOWCASE_PINS.items()):
        sched = ClusterScheduler(policy="frag_repack", **kwargs)
        records, _ = sched.run(trace_fn())
        assert sha(records) == expected, f"{name} drifted with twin loaded"
