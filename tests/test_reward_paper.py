"""Paper-fidelity tests: the reward metric and co-scheduling models must
reproduce the qualitative claims of §V and §VI (Figs. 5, 6, 8)."""
import pytest

from repro.configs import get_config, get_shape
from repro.core.cosched import corun_copies, sharing_table
from repro.core.hw import GiB, V5E_POD
from repro.core.power import InstanceLoad, throttle_factor
from repro.core.reward import sweep
from repro.core.slices import PROFILES, get_profile
from repro.core.utilization import scaling_curve
from repro.core.workload import WorkloadEstimate


def _wl(arch, shape):
    return WorkloadEstimate(get_config(arch), get_shape(shape))


# ---------------------------------------------------------------------------
# §VI-B / Fig. 8: reward-based selection
# ---------------------------------------------------------------------------
def test_alpha0_prefers_offload_when_footprint_slightly_exceeds():
    """Paper: with α=0 (pure utilization), a workload slightly above a slice
    prefers small-slice+offload over the next slice up. llama3 decode_32k
    (~527 GiB) vs the 512 GiB 2s.32c slice is exactly this case."""
    wl = _wl("llama3-8b", "decode_32k")
    assert 512 * GiB < wl.footprint_bytes() < 1024 * GiB
    best = sweep(wl, alpha=0.0)[0]
    assert best.plan is not None and best.plan.host_bytes > 0
    assert best.profile.name == "2s.32c"


def test_alpha1_prefers_full_pod_for_good_scalers():
    """Paper: α=1 selects the largest configuration for workloads with
    near-ideal performance scaling (their Qiskit/Llama3 analogue)."""
    wl = _wl("qwen2-vl-72b", "train_4k")
    best = sweep(wl, alpha=1.0)[0]
    assert best.profile.name == PROFILES[-1].name


def test_reward_monotone_in_alpha_for_perf():
    """Increasing α shifts selection toward larger (higher-perf) slices."""
    wl = _wl("llama3-8b", "decode_32k")
    chips = [sweep(wl, alpha=a)[0].profile.n_chips for a in (0.0, 0.5, 1.0)]
    assert chips == sorted(chips)


# ---------------------------------------------------------------------------
# §IV-C / Fig. 4: performance–resource scaling classes
# ---------------------------------------------------------------------------
def test_scaling_classes():
    # compute-bound big train: near-ideal scaling
    big = scaling_curve(_wl("qwen2-vl-72b", "train_4k"))
    pts = [r for r in big if r["fits"]]
    assert pts[-1]["rel_perf"] > 0.8 * pts[-1]["ideal"]
    # tiny-model decode: strongly sub-linear (latency/collective floor)
    small = scaling_curve(_wl("mamba2-130m", "decode_32k"))
    pts = [r for r in small if r["fits"]]
    assert pts[-1]["rel_perf"] < 0.5 * pts[-1]["ideal"]


# ---------------------------------------------------------------------------
# §V-A / Fig. 5: co-running throughput
# ---------------------------------------------------------------------------
def test_corun_improves_throughput_for_underutilizing_workloads():
    """Paper: NekRS/FAISS-class workloads gain up to ~2.5× from sharing; our
    analogue (tiny-model decode) must gain >1× from 16×1s sharing."""
    r = corun_copies(_wl("mamba2-130m", "decode_32k"), get_profile("1s.16c"), 16)
    assert r is not None and r.throughput_norm > 1.5


def test_corun_no_gain_for_compute_bound():
    """Paper: Qiskit/hotspot-class (compute-bound) see ≤ ~1× from sharing."""
    r = corun_copies(_wl("qwen2-vl-72b", "train_4k"), get_profile("4s.64c"), 4)
    if r is not None:  # may simply not fit on 64 chips without offload
        assert r.throughput_norm < 1.2


# ---------------------------------------------------------------------------
# §V-B / Figs. 6-7: energy + power throttling
# ---------------------------------------------------------------------------
def test_finest_sharing_lowest_energy():
    """Paper: MIG 7×1g consistently lowest energy. Our analogue: the finest
    fitting slice config minimizes energy_norm."""
    table = sharing_table(_wl("mamba2-130m", "decode_32k"))
    assert table, "no sharing configs fit"
    best = min(table, key=lambda r: r.energy_norm)
    assert best.config.endswith("1s.16c")
    assert best.energy_norm < 1.0  # sharing saves energy vs serial


def test_shared_power_cap_throttles_concurrent_compute():
    """Paper Fig. 7: isolation covers compute/memory but NOT power — many
    concurrent compute-heavy instances exceed the cap and throttle; a single
    instance never does."""
    hot = InstanceLoad(n_chips=16, u_compute=1.0, step_time=1.0)
    single = throttle_factor([hot])
    many = throttle_factor([hot] * 16)
    assert single == 1.0
    assert many < 1.0
